"""Container/artifact registry (paper Sec. V).

Hosts all versions of each artifact lineage plus **one CDMT index per
lineage** (maintained with node-copying as new versions are pushed).  The
registry never re-chunks on push — the client ships chunk fps + new chunks +
the new CDMT leaf sequence; the registry *incrementally* extends the
versioned index against the parent version's tree (cheap: only subtrees
whose leaf spans changed are re-hashed) and verifies the root matches the
client's claim, which doubles as the authentication mechanism.

Durability (``directory`` mode): registry state — version records, recipes,
tags, metadata — is persisted in an append-only, checksummed journal
(``registry.journal``, see :mod:`repro.core.journal`) with fsync-on-commit;
chunk payloads live in the :class:`~repro.core.store.ChunkStore` log and are
fsynced *before* the commit record is appended, so an acknowledged push
never references non-durable chunks.  ``Registry.__init__`` recovers by
replaying the snapshot (``registry.snap``, written by :meth:`compact`) and
then the journal, truncating any torn tail; replay rebuilds each lineage's
CDMT incrementally from the recorded recipes, so recovery hashing is
proportional to total *change* size, not versions × image size.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Set, \
    Tuple

from repro.obs import MetricsRegistry

from . import faults, hashing
from .cdmt import CDMT, CDMTParams, DEFAULT_PARAMS
from .errors import DeliveryError, JournalError
from .journal import Journal, ReplicationLog, scan_records, \
    write_snapshot_raw
from .store import DedupStore, Recipe
from .versioning import VersionedCDMT, VersionRecord

# journal record types
_J_COMMIT = 1
_J_META = 2
_J_EPOCH = 3    # replication epoch marker: journal/snapshot only, never
                # shipped — it describes the log, it is not part of it
_J_COMPACT = 4  # compaction boundary: first record of a freshly reset
                # journal, carrying the replication (epoch, head) its
                # snapshot covers — the durable signal that distinguishes
                # post-compact records from a stale journal whose
                # truncation was interrupted (including across GC epochs)
_J_TRIM = 5     # replication-base marker: snapshot-only, never shipped —
                # replay *resets* the log (empty, based at the recorded
                # offset), so a trimmed primary (or a snapshot-bootstrapped
                # standby) recovers with its absolute offsets intact
_J_TAIL = 6     # log-only record wrapper: snapshot-only, never shipped —
                # payload is a raw checksummed record that belongs to the
                # replication log *tail* (offsets base..head) but whose
                # state is already covered by the snapshot's collapsed
                # state records; replay feeds it to the log verbatim
                # without re-applying it


def _wire():
    from repro.delivery import wire   # lazy: see core.journal layering note
    return wire


class PushRejected(ValueError):
    """Push failed server-side verification (root mismatch / bad chunk /
    tag conflict)."""


@dataclasses.dataclass
class PushReceipt:
    lineage: str
    tag: str
    version: int
    chunks_received: int
    bytes_received: int
    index_bytes: int
    root: bytes
    nodes_created: int = 0      # CDMT nodes this push materialized
    nodes_hashed: int = 0       # node ids fingerprinted (O(k·depth) incr.)
    hash_calls: int = 0         # nodes_hashed + rolling-window cut tests
    deduplicated: bool = False  # tag+root already present; no new version


@dataclasses.dataclass
class SweepReport:
    """What :meth:`Registry.sweep` found (and, with ``drop``, reclaimed)."""
    live_chunks: int
    live_bytes: int
    unreferenced_chunks: int
    unreferenced_bytes: int
    retained_versions: int
    dropped_versions: int = 0
    dropped_chunks: int = 0
    reclaimed_bytes: int = 0


class Registry:
    """A registry: global chunk store + per-lineage versioned CDMT.

    With ``directory`` set the registry is durable: every committed push and
    metadata write is journaled (fsynced by default) and ``__init__``
    recovers the full index from disk.  Lineages are only durable through
    this API (``receive_push`` / ``put_metadata``) — commits made directly
    on a :class:`VersionedCDMT` bypass the journal.
    """

    def __init__(self, directory: Optional[str] = None,
                 cdmt_params: CDMTParams = DEFAULT_PARAMS,
                 sync: bool = True,
                 metrics: Optional[MetricsRegistry] = None):
        self.store = DedupStore(directory)
        self.cdmt_params = cdmt_params
        self.lineages: Dict[str, VersionedCDMT] = {}  # guarded-by: external(Registry is not MT-safe; RegistryServer._registry_lock serializes served access)
        self.recipes: Dict[Tuple[str, str], Recipe] = {}   # guarded-by: external(RegistryServer._registry_lock)
        self.metadata: Dict[Tuple[str, str], bytes] = {}   # guarded-by: external(RegistryServer._registry_lock)
        self._journal: Optional[Journal] = None
        self._snap_path: Optional[str] = None
        # standby role: a JournalFollower marks its registry read-only so a
        # misdirected client push fails loudly instead of forking the
        # lineage history away from the primary; promote() clears it
        self.read_only = False  # guarded-by: external(RegistryServer._registry_lock)
        # per-instance metrics: the delivery frontends adopt this registry's
        # so one scrape covers commit latency + frontend + cache together
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m_commit = self.metrics.histogram(
            "registry_commit_seconds",
            "receive_push latency: verify + store + journal + index"
        ).labels()
        self._m_apply = self.metrics.histogram(
            "replication_apply_seconds",
            "standby apply latency for one shipped record").labels()
        self._m_repl_head = self.metrics.gauge(
            "replication_log_head", "replication log head (records this "
            "epoch)").labels()
        self._m_repl_epoch = self.metrics.gauge(
            "replication_epoch", "current replication epoch").labels()
        self._m_repl_base = self.metrics.gauge(
            "replication_log_base", "replication log base (lowest offset "
            "still held after trimming)").labels()
        self._m_repl_records = self.metrics.gauge(
            "replication_log_records", "records currently held in the "
            "in-memory replication log (head - base)").labels()
        self._m_repl_trimmed = self.metrics.counter(
            "replication_log_trimmed_total", "replication log records "
            "dropped by trimming below the minimum acked offset").labels()
        self._m_bootstrap_bytes = self.metrics.counter(
            "bootstrap_snapshot_bytes_total", "encoded state-record bytes "
            "adopted via snapshot bootstrap").labels()
        self._m_bootstrap = self.metrics.histogram(
            "bootstrap_apply_seconds", "snapshot-bootstrap latency: "
            "verify + persist + install").labels()
        # replication tap: every committed record, in commit order — what a
        # standby follows over JOURNAL_SHIP (see repro.delivery.net).  Fed
        # during recovery too, so resume offsets survive a primary restart.
        self.replication = ReplicationLog()
        if directory is not None:
            self._snap_path = os.path.join(directory, "registry.snap")
            if os.path.exists(self._snap_path):
                # snapshots are written atomically (temp + fsync + rename),
                # so unlike the append-only journal they have no legitimate
                # torn tail: any undecodable record is real corruption and
                # must fail loudly, not silently drop the versions after it
                records, good_end, size = scan_records(self._snap_path)
                if good_end != size:
                    raise JournalError(
                        f"snapshot {self._snap_path} is corrupt at byte "
                        f"{good_end} of {size}")
                for rtype, payload in records:
                    self._recover_record(rtype, payload)
            had_snapshot = os.path.exists(self._snap_path)
            self._journal = Journal(
                os.path.join(directory, "registry.journal"), sync=sync,
                metrics=self.metrics)
            self._recover_journal(self._journal.replay(),
                                  has_snapshot=had_snapshot)

    # -- recovery -------------------------------------------------------------

    def _recover_record(self, rtype: int, payload: bytes) -> None:
        """Replay one persisted record at startup: epoch markers restore
        the replication epoch (compaction boundaries are structural and
        skipped here); everything else is applied AND fed to the
        replication log in persisted order, so resume offsets survive a
        restart."""
        if rtype == _J_EPOCH:
            epoch, _ = _wire().decode_uvarint(payload, 0)
            self.replication.set_epoch(epoch)
            return
        if rtype == _J_TRIM:
            base, _ = _wire().decode_uvarint(payload, 0)
            # reset, not trim: any records fed so far were the snapshot's
            # collapsed *state* section, which is not part of the log tail
            self.replication.reset_to(self.replication.epoch, base)
            return
        if rtype == _J_TAIL:
            self.replication.append_raw(payload)
            return
        if rtype == _J_COMPACT:
            return
        self._apply(rtype, payload)
        self.replication.append(rtype, payload)

    def _recover_journal(self, jrecords: List[Tuple[int, bytes]],
                         has_snapshot: bool) -> None:
        """Replay the journal after the snapshot, deciding whether its
        records are post-compaction state (feed them) or a stale journal
        a crash left un-truncated (skip them — replaying would double-feed
        the replication tap, shift every standby's offset, or resurrect
        GC-dropped versions).

        The decision is the ``_J_COMPACT`` boundary marker ``compact()``
        writes as the first record of every freshly reset journal, carrying
        the replication ``(epoch, head)`` its snapshot covers:

        * journal epoch **behind** the snapshot's → the whole journal
          predates a GC rollover the snapshot includes (sweep died between
          its snapshot and the journal reset) → stale, skip;
        * same epoch, marker head == snapshot head → the journal continues
          the snapshot → feed;
        * same epoch, marker head behind → a later compact's truncation was
          interrupted; the body must byte-match the snapshot's tail
          (anything else is corruption) → stale, skip;
        * journal ahead of the snapshot (epoch or head) → the snapshot
          regressed — real corruption, fail loudly.

        A snapshot with a trimmed base (``_J_TRIM`` — a trimmed primary or
        a snapshot-bootstrapped standby) adds one rule: a journal whose
        marker head lies **below the base** predates the trim/bootstrap
        point entirely (bootstrap crashed between the snapshot rename and
        the journal reset), as does a marker-less journal next to a
        trimmed snapshot (a follower's plain journal at bootstrap time) —
        both are stale, no byte comparison possible or needed.

        Without a snapshot the journal is the sole authority and is fed
        whole.  Journals from before the marker existed fall back to the
        byte-suffix comparison.  A detected stale journal is truncated on
        the spot (the interrupted compaction is finished), so post-crash
        appends never mix stale and fresh records.
        """
        wire = _wire()
        snap_epoch = self.replication.epoch    # as set by the snapshot (or 0)
        snap_head = self.replication.head()
        snap_base = self.replication.base
        marker: Optional[Tuple[int, int]] = None
        if jrecords and jrecords[0][0] == _J_COMPACT:
            m_epoch, off = wire.decode_uvarint(jrecords[0][1], 0)
            m_head, _ = wire.decode_uvarint(jrecords[0][1], off)
            marker = (m_epoch, m_head)
            jrecords = jrecords[1:]
        epochs = [(t, p) for t, p in jrecords if t == _J_EPOCH]
        body = [(t, p) for t, p in jrecords
                if t not in (_J_EPOCH, _J_COMPACT)]
        journal_epoch = marker[0] if marker is not None else 0
        for _t, p in epochs:
            e, _ = wire.decode_uvarint(p, 0)
            journal_epoch = max(journal_epoch, e)
        stale = False
        if body and has_snapshot:
            if journal_epoch > snap_epoch:
                raise JournalError(
                    f"journal is at replication epoch {journal_epoch} but "
                    f"the snapshot only covers epoch {snap_epoch} — the "
                    f"snapshot regressed")
            if journal_epoch < snap_epoch:
                stale = True               # predates the GC rollover
            elif marker is not None:
                if marker[1] > snap_head:
                    raise JournalError(
                        f"journal claims a compaction at replication head "
                        f"{marker[1]} but the snapshot only covers "
                        f"{snap_head}")
                if marker[1] < snap_base:
                    stale = True   # predates the trim/bootstrap point
                elif marker[1] < snap_head:
                    if not self._is_replication_tail(body):
                        raise JournalError(
                            "journal and snapshot disagree about the "
                            "records after the last compaction")
                    stale = True
            else:
                stale = snap_base > 0 or self._is_replication_tail(body)
        if stale:
            # finish the interrupted truncation: later appends must land on
            # a clean post-compact journal, never after stale records
            self._journal.reset()
            self._journal.append(_J_COMPACT,
                                 wire.encode_uvarint(snap_epoch)
                                 + wire.encode_uvarint(snap_head))
            return
        for rtype, payload in epochs:      # epochs first: idempotent values
            self._recover_record(rtype, payload)
        for rtype, payload in body:
            self._recover_record(rtype, payload)

    def _is_replication_tail(self, records: Sequence[Tuple[int, bytes]]
                             ) -> bool:
        """True iff ``records`` re-encode byte-identically to the last
        ``len(records)`` records already fed to the replication log."""
        wire = _wire()
        raws = [wire.encode_record(t, p) for t, p in records]
        return raws == self.replication.tail(len(raws))

    # -- server-side API (what the wire protocol calls) -----------------------

    def lineage(self, name: str) -> VersionedCDMT:
        if name not in self.lineages:
            self.lineages[name] = VersionedCDMT(params=self.cdmt_params)
        return self.lineages[name]

    def latest_index(self, lineage: str) -> Optional[CDMT]:
        lin = self.lineages.get(lineage)
        if lin is None or not lin.roots:
            return None
        return lin.get_version(lin.roots[-1].version)

    # api-boundary
    def index_for_tag(self, lineage: str, tag: str) -> CDMT:
        """CDMT for ``lineage:tag``; :class:`DeliveryError` (a clean
        protocol-level error, not a bare ``KeyError``) when unknown."""
        lin = self.lineages.get(lineage)
        if lin is None:
            raise DeliveryError(f"unknown lineage {lineage!r}")
        version = lin.version_of(tag)
        if version is None:
            raise DeliveryError(f"unknown tag {lineage}:{tag}")
        return lin.get_version(version)

    # api-boundary
    def branch_root_at(self, lineage: str, branch: str,
                       version: int) -> Optional[bytes]:
        """Branch-at-version query: the CDMT root the branch head
        ``branch`` (tags follow ``branch@rev``) held at ``version`` in
        ``lineage``; ``None`` if the branch had no commit yet.

        Answers survive restart and compaction: the backing
        ``mod_history`` is rebuilt from journaled commit records during
        recovery (see ``VersionedCDMT.branch_root_at``)."""
        lin = self.lineages.get(lineage)
        if lin is None:
            raise DeliveryError(f"unknown lineage {lineage!r}")
        return lin.branch_root_at(branch, version)

    def has_chunks(self, fps: Iterable[bytes]) -> List[bytes]:
        """Which of ``fps`` the registry is missing."""
        return self.store.missing(fps)

    # api-boundary
    def receive_push(self, lineage: str, tag: str, recipe: Recipe,
                     chunks: Dict[bytes, bytes],
                     parent_version: Optional[int] = None,
                     claimed_root: Optional[bytes] = None,
                     claimed_params: Optional[CDMTParams] = None,
                     chunks_verified: bool = False) -> PushReceipt:
        """Accept a push: verify, store new chunks, extend the versioned CDMT.

        Verification (paper Sec. V — the root check doubles as the
        authentication mechanism):

        * every pushed chunk's blake2b must equal its claimed fingerprint
          (skipped with ``chunks_verified`` — the wire frontend already
          hashes every payload during ``decode_chunk_batch``);
        * every fingerprint the recipe references must be covered — either
          pushed now or already stored — so a committed version is always
          reconstructable, and every pushed chunk must be referenced by the
          recipe, so no unreachable data enters the store;
        * with ``claimed_root`` given, the CDMT built from the recipe's leaf
          sequence must hash to exactly that root.  When the claim's params
          match the registry's, this build is **incremental** against the
          parent version's tree (O(changed subtrees), not O(n_leaves)) and
          is the very tree the commit then installs — one build serves both
          verification and maintenance, with no throwaway full rebuild.
          With foreign ``claimed_params`` the claim is verified against a
          throwaway build with those params (a differently-cut tree cannot
          be donated to the lineage);
        * re-pushing an existing tag with the same root is idempotent
          (``deduplicated`` receipt, no new version); with a different root
          it is rejected — a tag binds one root, forever.

        All checks run *before* any state is mutated (new CDMT nodes land in
        a copy-on-write overlay); a failed push leaves the registry
        untouched and raises :class:`PushRejected`.  On success, chunks are
        fsynced and the commit is journaled before the receipt is returned.
        """
        t0 = time.perf_counter()
        if self.read_only:
            raise PushRejected(
                f"push {lineage}:{tag}: registry is a read-only standby — "
                f"push to the primary, or promote this replica first")
        if len(recipe.fps) != len(recipe.sizes):
            raise PushRejected(
                f"push {lineage}:{tag}: recipe has {len(recipe.fps)} "
                f"fingerprints but {len(recipe.sizes)} sizes")
        if not chunks_verified:
            for fp, data in chunks.items():
                if hashing.chunk_fingerprint(data) != fp:
                    raise PushRejected(
                        f"push {lineage}:{tag}: chunk {fp.hex()[:12]} payload "
                        f"does not hash to its fingerprint")
        referenced = set(recipe.fps)
        stray = [fp for fp in chunks if fp not in referenced]
        if stray:
            raise PushRejected(
                f"push {lineage}:{tag}: {len(stray)} pushed chunk(s) not "
                f"referenced by the recipe (first: {stray[0].hex()[:12]}) — "
                f"refusing to store unreachable data")
        unavailable = [fp for fp in self.store.missing(recipe.fps)
                       if fp not in chunks]
        if unavailable:
            raise PushRejected(
                f"push {lineage}:{tag}: recipe references "
                f"{len(unavailable)} chunk(s) neither pushed nor stored "
                f"(first: {unavailable[0].hex()[:12]})")

        lin = self.lineages.get(lineage)
        new_lineage = lin is None
        if new_lineage:
            lin = VersionedCDMT(params=self.cdmt_params)
        if parent_version is not None and not 0 <= parent_version < len(lin.roots):
            raise PushRejected(
                f"push {lineage}:{tag}: unknown parent version "
                f"{parent_version}")
        params = claimed_params or self.cdmt_params
        if claimed_root is not None and params != self.cdmt_params:
            # foreign tree parameters: verify the claim against a throwaway
            # build with those params; the lineage index below still uses
            # the registry's own params (a differently-cut tree cannot be
            # donated)
            check = CDMT.build(recipe.fps, params=params)
            if check.root != claimed_root:
                raise PushRejected(
                    f"push {lineage}:{tag}: rebuilt CDMT root "
                    f"{check.root.hex()[:12] if check.root else None} != "
                    f"claimed {claimed_root.hex()[:12]}")
            claimed_root = None        # claim consumed; registry-params build
        tree, new_nodes, stats = lin.build_next(recipe.fps,
                                                parent=parent_version)
        if claimed_root is not None and tree.root != claimed_root:
            raise PushRejected(
                f"push {lineage}:{tag}: rebuilt CDMT root "
                f"{tree.root.hex()[:12] if tree.root else None} != "
                f"claimed {claimed_root.hex()[:12]}")
        existing = lin.version_of(tag)
        if existing is not None:
            prev = lin.roots[existing]
            if prev.root != tree.root:
                raise PushRejected(
                    f"push {lineage}:{tag}: tag is already bound to a "
                    f"different root — push under a new tag")
            self._m_commit.observe(time.perf_counter() - t0)
            return PushReceipt(lineage=lineage, tag=tag, version=prev.version,
                               chunks_received=0, bytes_received=0,
                               index_bytes=tree.index_size_bytes(),
                               root=prev.root, hash_calls=stats.hash_calls,
                               nodes_hashed=stats.nodes_hashed,
                               deduplicated=True)

        # -- verified: mutate (chunks → journal → recipes → index) ------------
        # Write-ahead order: the commit record is journaled BEFORE any
        # in-memory index state changes.  If the append fails (ENOSPC, closed
        # journal) the push errors out with the index untouched, so a client
        # retry re-runs verification and re-journals — never a success
        # receipt for a version that would vanish on restart.  (Chunks land
        # first: they are content-addressed, so an orphan from a failed push
        # is idle data, not corruption.)
        nbytes = 0
        nchunks = 0
        for fp, data in chunks.items():
            if self.store.chunks.put(fp, data):
                nchunks += 1
                nbytes += len(data)
        self.store.chunks.sync()       # chunks durable before the commit record
        parent_resolved = (parent_version if parent_version is not None
                           else lin.head_version())
        pending = VersionRecord(version=len(lin.roots), tag=tag,
                                root=tree.root, parent=parent_resolved,
                                n_leaves=len(recipe.fps), new_nodes=0)
        # encode ONCE: the journal and the replication log get the same
        # bytes, so a shipped record is byte-identical to the journaled one
        commit_raw = _wire().encode_record(
            _J_COMMIT, _encode_commit(lineage, tag, pending, recipe))
        if self._journal is not None:
            self._journal.append_raw(commit_raw)
        self.recipes[(lineage, tag)] = recipe
        self.store.recipes[f"{lineage}:{tag}"] = recipe
        rec = lin.commit(recipe.fps, tag=tag, parent=parent_version,
                         tree=tree, new_nodes=new_nodes)
        assert rec.version == pending.version and rec.root == pending.root
        if new_lineage:
            self.lineages[lineage] = lin
        # replication tap: only *committed* records are shipped to standbys
        self.replication.append_raw(commit_raw)
        self._m_repl_head.set(self.replication.head())
        self._m_commit.observe(time.perf_counter() - t0)
        return PushReceipt(lineage=lineage, tag=tag, version=rec.version,
                           chunks_received=nchunks, bytes_received=nbytes,
                           index_bytes=tree.index_size_bytes(), root=rec.root,
                           nodes_created=rec.new_nodes,
                           nodes_hashed=stats.nodes_hashed,
                           hash_calls=stats.hash_calls)

    # api-boundary
    def serve_chunks(self, fps: Sequence[bytes]) -> Dict[bytes, bytes]:
        """Chunk payloads for ``fps``; an unknown fingerprint raises a clean
        :class:`DeliveryError` instead of leaking a bare ``KeyError``
        through the wire frontend."""
        out: Dict[bytes, bytes] = {}
        for fp in fps:
            try:
                out[fp] = self.store.chunks.get(fp)
            except KeyError:
                raise DeliveryError(
                    f"registry cannot serve unknown chunk "
                    f"{fp.hex()[:12]}") from None
        return out

    # api-boundary
    def recipe_for(self, lineage: str, tag: str) -> Recipe:
        recipe = self.recipes.get((lineage, tag))
        if recipe is None:
            raise DeliveryError(f"no recipe for {lineage}:{tag}")
        return recipe

    def tags(self, lineage: str) -> List[str]:
        lin = self.lineages.get(lineage)
        return lin.tags() if lin else []

    # -- small metadata blobs (checkpoint manifests etc.) ---------------------

    # api-boundary
    def put_metadata(self, lineage: str, tag: str, blob: bytes) -> None:
        if self.read_only:
            raise PushRejected(
                f"metadata write {lineage}:{tag}: registry is a read-only "
                f"standby — write to the primary, or promote this replica")
        # write-ahead like receive_push: journal first, so a failed append
        # never leaves in-memory state a later compact() would resurrect
        raw = _wire().encode_record(_J_META, _encode_meta(lineage, tag, blob))
        if self._journal is not None:
            self._journal.append_raw(raw)
        self.metadata[(lineage, tag)] = blob
        self.replication.append_raw(raw)

    # api-boundary
    def get_metadata(self, lineage: str, tag: str) -> bytes:
        blob = self.metadata.get((lineage, tag))
        if blob is None:
            raise DeliveryError(f"no metadata for {lineage}:{tag}")
        return blob

    # -- garbage collection --------------------------------------------------

    # api-boundary
    def sweep(self, retain_tags: Optional[Mapping[str, Iterable[str]]] = None,
              drop: bool = False) -> SweepReport:
        """Mark-and-sweep over recipes: report — and with ``drop=True``
        reclaim — chunks no retained version references.

        ``retain_tags`` maps lineage → the tags to pin; lineages absent from
        the mapping retain **all** their tags, and ``None`` (the default)
        retains everything — the sweep then reports only true orphans
        (chunks referenced by no recipe at all).  Unknown pins raise
        ``ValueError``: a typo in a retention policy must not silently
        widen the sweep.

        With ``drop=True`` the un-pinned versions are forgotten first (each
        affected lineage's versioned CDMT is rebuilt from the retained
        recipes — version numbers are reassigned densely; tags remain the
        stable names), then the journal is compacted so a restart replays
        only retained state, and only *then* is the chunk log compacted.
        That ordering is what makes the sweep journal-safe: a crash between
        journal and chunk compaction leaves garbage chunks (harmless,
        re-sweepable), never a journaled version whose chunks are gone.
        """
        pins: Optional[Dict[str, Set[str]]] = None
        if retain_tags is not None:
            # normalize up front: a one-shot iterator as a value must not be
            # consumed by validation and then read as empty by the sweep —
            # that would silently drop the pinned versions themselves
            pins = {lin: set(tags) for lin, tags in retain_tags.items()}
            for lin, tags in pins.items():
                if lin not in self.lineages:
                    raise ValueError(f"sweep: unknown lineage {lin!r}")
                for t in tags:
                    if (lin, t) not in self.recipes:
                        raise ValueError(f"sweep: unknown pin {lin}:{t}")
        retained: Set[Tuple[str, str]] = set()
        dropped_pairs: List[Tuple[str, str]] = []
        for lineage, tag in self.recipes:
            if pins is None or lineage not in pins or tag in pins[lineage]:
                retained.add((lineage, tag))
            else:
                dropped_pairs.append((lineage, tag))

        live: Set[bytes] = set()
        for pair in retained:
            live.update(self.recipes[pair].fps)
        chunks = self.store.chunks
        dead = [fp for fp in chunks.fingerprints() if fp not in live]
        dead_bytes = sum(chunks.chunk_size(fp) for fp in dead)
        report = SweepReport(
            live_chunks=chunks.n_chunks() - len(dead),
            live_bytes=chunks.stored_bytes() - dead_bytes,
            unreferenced_chunks=len(dead),
            unreferenced_bytes=dead_bytes,
            retained_versions=len(retained),
            dropped_versions=len(dropped_pairs))
        if not drop:
            return report

        # 1) forget un-pinned versions: rebuild each affected lineage from
        #    its retained recipes (in original version order)
        by_lineage: Dict[str, List[str]] = {}
        for lineage, tag in dropped_pairs:
            by_lineage.setdefault(lineage, []).append(tag)
        for lineage in by_lineage:
            old = self.lineages[lineage]
            keep = [rec for rec in old.version_records()
                    if (lineage, rec.tag) in retained]
            if keep:
                fresh = VersionedCDMT(params=self.cdmt_params)
                for rec in keep:
                    fresh.commit(self.recipes[(lineage, rec.tag)].fps,
                                 tag=rec.tag)
                self.lineages[lineage] = fresh
            else:
                del self.lineages[lineage]
        for lineage, tag in dropped_pairs:
            del self.recipes[(lineage, tag)]
            self.store.recipes.pop(f"{lineage}:{tag}", None)
            self.metadata.pop((lineage, tag), None)
        # dropping versions reassigns version numbers, so every standby's
        # resume offset is now meaningless: roll the replication log into a
        # new epoch and re-seed it with the retained-only state (a *fresh*
        # standby can still sync from offset 0; followers at the old epoch
        # are refused and must full-resync)
        if dropped_pairs:
            self.replication.rollover()
            for rtype, payload in self._state_records():
                self.replication.append(rtype, payload)
            self._m_repl_epoch.set(self.replication.epoch)
            self._m_repl_head.set(self.replication.head())
        # 2) journal safety: persist the retained-only state BEFORE any
        #    chunk payload disappears
        if self._journal is not None:
            self.compact()
        # 3) reclaim the chunk log
        dropped_chunks, reclaimed = chunks.compact(live)
        report.dropped_chunks = dropped_chunks
        report.reclaimed_bytes = reclaimed
        return report

    # -- durability ----------------------------------------------------------

    def _apply(self, rtype: int, payload: bytes) -> None:
        """Replay one journal/snapshot record.  Unknown record types are
        skipped (forward compatibility); inconsistent records raise
        :class:`JournalError`."""
        if rtype == _J_COMMIT:
            lineage, tag, version, parent, root, recipe = \
                _decode_commit(payload)
            lin = self.lineage(lineage)
            try:
                rec = lin.commit(recipe.fps, tag=tag, parent=parent)
            except ValueError as e:
                raise JournalError(f"replay {lineage}:{tag}: {e}") from None
            if rec.version != version:
                raise JournalError(
                    f"replay {lineage}:{tag}: assigned version {rec.version} "
                    f"!= journaled {version}")
            if rec.root != root:
                raise JournalError(
                    f"replay {lineage}:{tag}: rebuilt root "
                    f"{rec.root.hex()[:12] if rec.root else None} != "
                    f"journaled {root.hex()[:12] if root else None}")
            self.recipes[(lineage, tag)] = recipe
            self.store.recipes[f"{lineage}:{tag}"] = recipe
        elif rtype == _J_META:
            lineage, tag, blob = _decode_meta(payload)
            self.metadata[(lineage, tag)] = blob

    # api-boundary
    def apply_replicated(self, rtype: int, payload: bytes,
                         expected_seq: Optional[int] = None,
                         raw: Optional[bytes] = None) -> bool:
        """Apply one record shipped from a primary (standby-side replay).

        ``expected_seq`` is the record's offset in the primary's replication
        log; a record at an offset this registry has already applied is
        **skipped** (returns ``False``) — duplicate delivery after a lost
        ack or a crash between apply and ack is idempotent — while a gap
        (offset ahead of our head) raises :class:`JournalError` instead of
        silently corrupting version numbering.

        Write order mirrors ``receive_push``: any chunk payloads the record
        references must already be in the store (the follower fetches them
        first); they are fsynced, then the record is journaled, then applied
        — so an acked offset never points at non-durable standby state.

        The record itself was checksum-verified on decode
        (:func:`repro.delivery.wire.decode_record_frame`) before it reaches
        this method; ``raw`` is that verified encoding — passing it through
        avoids re-encoding and re-journals the primary's exact bytes.
        """
        if expected_seq is not None:
            head = self.replication.head()
            if expected_seq < head:
                return False               # duplicate delivery: already applied
            if expected_seq > head:
                raise JournalError(
                    f"replication gap: record offset {expected_seq} but "
                    f"standby has only applied {head}")
        t0 = time.perf_counter()
        if raw is None:
            raw = _wire().encode_record(rtype, payload)
        if self._journal is not None:
            self.store.chunks.sync()   # referenced chunks durable first
            self._journal.append_raw(raw)
        self._apply(rtype, payload)
        self.replication.append_raw(raw)
        self._m_repl_head.set(self.replication.head())
        self._m_apply.observe(time.perf_counter() - t0)
        return True

    def set_replication_epoch(self, epoch: int) -> None:
        """Adopt a replication epoch (standby role: a fresh follower learns
        the primary's epoch on first contact).  Journaled as an epoch
        marker, so the pairing of *offset × epoch* survives a standby
        restart — a follower must never resume an old-epoch offset against
        a newer-epoch primary."""
        if self._journal is not None:
            self._journal.append(_J_EPOCH, _wire().encode_uvarint(epoch))
        self.replication.set_epoch(epoch)
        self._m_repl_epoch.set(epoch)

    def _state_records(self) -> List[Tuple[int, bytes]]:
        """The current committed state as a compacted record sequence —
        what a snapshot persists and what a rolled-over replication log is
        re-seeded with."""
        records: List[Tuple[int, bytes]] = []
        for lineage, lin in self.lineages.items():
            for rec in lin.version_records():
                recipe = self.recipes.get((lineage, rec.tag))
                if recipe is not None:
                    records.append(
                        (_J_COMMIT, _encode_commit(lineage, rec.tag, rec,
                                                   recipe)))
        for (lineage, tag), blob in self.metadata.items():
            records.append((_J_META, _encode_meta(lineage, tag, blob)))
        return records

    def compact(self) -> None:
        """Write the current state as a snapshot and truncate the journal.

        The snapshot has three sections, replayed in order by
        ``_recover_record``:

        1. the replication epoch marker, then the **collapsed state
           records** (one commit per retained version plus current
           metadata) — these rebuild the registry's state; the trimmed
           record-history prefix no longer exists anywhere, so the state
           must be self-contained;
        2. a ``_J_TRIM`` marker carrying the log's trimmed ``base`` —
           replay *resets* the replication log (wiping the state section's
           feed) to an empty log based at that offset;
        3. the **live log tail** (offsets ``base..head``), each raw record
           wrapped in ``_J_TAIL`` so replay feeds it to the log verbatim
           without re-applying state the collapsed section already covers.

        A restart therefore rebuilds both the state and the log
        byte-identically (base included), so every standby's resume offset
        stays valid across primary compactions and restarts.  The log no
        longer grows with the epoch's whole record history:
        :meth:`trim_replication` drops the prefix every tracked replica
        has acked, and fresh standbys join from :meth:`state_snapshot`
        (``Op.SNAPSHOT_SHIP``) instead of offset 0 — closing the trade
        this docstring used to document.

        Crash-safe in every window: the snapshot lands by atomic rename;
        the reset journal immediately receives a ``_J_COMPACT`` boundary
        marker naming the head the snapshot covers, so recovery can tell a
        post-compaction journal from a stale one whose truncation was
        interrupted (and in the latter case skips it and finishes the
        truncation — no double-apply, no offset shift).
        """
        if self._journal is None:
            return
        wire = _wire()
        epoch = self.replication.epoch
        head = self.replication.head()
        epoch_raw = wire.encode_record(_J_EPOCH, wire.encode_uvarint(epoch))
        state_raws = [wire.encode_record(t, p)
                      for t, p in self._state_records()]
        trim_raw = wire.encode_record(
            _J_TRIM, wire.encode_uvarint(self.replication.base))
        tail_raws = [wire.encode_record(_J_TAIL, r)
                     for r in self.replication.dump()]
        write_snapshot_raw(self._snap_path,
                           [epoch_raw] + state_raws + [trim_raw] + tail_raws)
        faults.fire("compact.after_snapshot")
        self._journal.reset()
        faults.fire("compact.before_marker")
        self._journal.append(_J_COMPACT, wire.encode_uvarint(epoch)
                             + wire.encode_uvarint(head))

    def trim_replication(self, min_acked: int) -> int:
        """Drop replication-log records below ``min_acked`` (the lowest
        offset every tracked replica has acked — the serving frontend calls
        this after recording each ack) and, when records were dropped,
        persist the bounded log via :meth:`compact`.  Returns the number of
        records dropped.

        In-memory trim first, durable compact second: a crash between the
        two recovers the *untrimmed* log from the previous snapshot — a
        larger memory footprint until the next trim, never a lost record.
        """
        dropped = self.replication.trim_to(min_acked)
        if dropped:
            self._m_repl_trimmed.inc(dropped)
            faults.fire("trim.before_compact")
            if self._journal is not None:
                self.compact()
        self._m_repl_base.set(self.replication.base)
        self._m_repl_records.set(self.replication.head()
                                 - self.replication.base)
        return dropped

    def state_snapshot(self) -> Tuple[int, int, List[bytes]]:
        """The collapsed current state as encoded checksummed records, plus
        the replication position ``(epoch, head)`` it corresponds to — what
        ``Op.SNAPSHOT_SHIP`` streams to a bootstrapping standby.

        Collapsed means O(live state), not O(record history): one commit
        record per retained version plus each metadata key's current value.
        The caller must hold the serving lock so position and state agree.
        """
        wire = _wire()
        epoch = self.replication.epoch
        head = self.replication.head()
        raws = [wire.encode_record(t, p) for t, p in self._state_records()]
        return epoch, head, raws

    # api-boundary
    def bootstrap_from_snapshot(self, epoch: int, head: int,
                                records: Sequence[Tuple[int, bytes, bytes]]
                                ) -> int:
        """Adopt a primary's collapsed state snapshot (standby bootstrap).

        ``records`` are ``(rtype, payload, raw)`` triples as verified by
        :func:`repro.delivery.wire.decode_record_frame`; ``(epoch, head)``
        is the replication position the snapshot corresponds to — after
        this returns, ordinary ``JOURNAL_SHIP`` resumes from ``head``.
        Any chunk payloads the records reference must already be in the
        store (the follower fetches them first, like ordinary replay).

        Trust-but-reverify: before anything is persisted the records are
        replayed into a scratch registry, re-verifying every commit's CDMT
        root against its recipe — adopted state from a lying or corrupted
        primary is rejected (:class:`JournalError`) with this registry
        untouched.  Persistence is then strictly before installation: the
        snapshot file lands atomically (epoch + state records + a
        ``_J_TRIM`` marker at ``head``), the journal is reset behind a
        ``_J_COMPACT`` marker, and only then is the verified state
        installed in memory — so every crash window either recovers the
        pre-bootstrap state (the bootstrap restarts idempotently) or the
        complete post-bootstrap state, never a torn mixture.

        Returns the number of state records adopted.
        """
        t0 = time.perf_counter()
        wire = _wire()
        # 1) re-verify into a scratch registry (same CDMT params): a bad
        #    record is detected before any durable state changes
        scratch = Registry(cdmt_params=self.cdmt_params)
        for rtype, payload, _raw in records:
            scratch._apply(rtype, payload)
        raws = [raw for _t, _p, raw in records]
        faults.fire("bootstrap.before_snapshot")
        # 2) persist: recovery of this snapshot rebuilds exactly the state
        #    installed below (records applied; log empty, based at head)
        if self._journal is not None:
            self.store.chunks.sync()   # referenced chunks durable first
            epoch_raw = wire.encode_record(_J_EPOCH,
                                           wire.encode_uvarint(epoch))
            trim_raw = wire.encode_record(_J_TRIM,
                                          wire.encode_uvarint(head))
            write_snapshot_raw(self._snap_path,
                               [epoch_raw] + raws + [trim_raw])
            faults.fire("bootstrap.after_snapshot")
            self._journal.reset()
            faults.fire("bootstrap.before_marker")
            self._journal.append(_J_COMPACT, wire.encode_uvarint(epoch)
                                 + wire.encode_uvarint(head))
        faults.fire("bootstrap.after_persist")
        # 3) install: adopt the verified scratch state wholesale
        self.lineages = scratch.lineages
        self.recipes = scratch.recipes
        self.metadata = scratch.metadata
        self.store.recipes.clear()
        self.store.recipes.update(scratch.store.recipes)
        self.replication.reset_to(epoch, head)
        self._m_repl_epoch.set(epoch)
        self._m_repl_head.set(head)
        self._m_repl_base.set(head)
        self._m_repl_records.set(0)
        self._m_bootstrap_bytes.inc(sum(len(r) for r in raws))
        self._m_bootstrap.observe(time.perf_counter() - t0)
        return len(raws)

    def journal_size_bytes(self) -> int:
        return self._journal.size_bytes() if self._journal is not None else 0

    def close(self) -> None:
        if self._journal is not None:
            self._journal.close()
        self.store.close()


# ---------------------------------------------------- journal record payloads

def record_chunk_fps(rtype: int, payload: bytes) -> List[bytes]:
    """The chunk fingerprints a replicated record references — what a
    standby must hold *before* replaying it (a commit record's recipe fps;
    metadata records reference none).  Unknown record types reference none
    (forward compatibility: they are skipped by ``_apply`` too)."""
    if rtype != _J_COMMIT:
        return []
    return list(_decode_commit(payload)[5].fps)


def _encode_commit(lineage: str, tag: str, rec: VersionRecord,
                   recipe: Recipe) -> bytes:
    from repro.delivery import wire     # lazy: see journal layering note
    out = bytearray()
    for s in (lineage, tag):
        b = s.encode("utf-8")
        out += wire.encode_uvarint(len(b))
        out += b
    out += wire.encode_uvarint(rec.version)
    if rec.parent is None:
        out += wire.encode_uvarint(0)
    else:
        out += wire.encode_uvarint(1)
        out += wire.encode_uvarint(rec.parent)
    if rec.root is None:
        out += wire.encode_uvarint(0)
    else:
        out += wire.encode_uvarint(1)
        out += rec.root
    out += wire.encode_recipe(recipe)   # trailing self-verifying RECIPE frame
    return bytes(out)


def _decode_commit(payload: bytes
                   ) -> Tuple[str, str, int, Optional[int], Optional[bytes],
                              Recipe]:
    from repro.delivery import wire
    off = 0
    strs: List[str] = []
    for _ in range(2):
        n, off = wire.decode_uvarint(payload, off)
        if off + n > len(payload):
            raise JournalError("truncated commit record string")
        strs.append(payload[off:off + n].decode("utf-8"))
        off += n
    version, off = wire.decode_uvarint(payload, off)
    has_parent, off = wire.decode_uvarint(payload, off)
    parent: Optional[int] = None
    if has_parent:
        parent, off = wire.decode_uvarint(payload, off)
    has_root, off = wire.decode_uvarint(payload, off)
    root: Optional[bytes] = None
    if has_root:
        root = payload[off:off + hashing.DIGEST_SIZE]
        if len(root) != hashing.DIGEST_SIZE:
            raise JournalError("truncated commit record root")
        off += hashing.DIGEST_SIZE
    recipe = wire.decode_recipe(payload[off:])
    return strs[0], strs[1], version, parent, root, recipe


def _encode_meta(lineage: str, tag: str, blob: bytes) -> bytes:
    from repro.delivery import wire
    out = bytearray()
    for b in (lineage.encode("utf-8"), tag.encode("utf-8"), blob):
        out += wire.encode_uvarint(len(b))
        out += b
    return bytes(out)


def _decode_meta(payload: bytes) -> Tuple[str, str, bytes]:
    from repro.delivery import wire
    off = 0
    parts: List[bytes] = []
    for _ in range(3):
        n, off = wire.decode_uvarint(payload, off)
        if off + n > len(payload):
            raise JournalError("truncated metadata record")
        parts.append(payload[off:off + n])
        off += n
    return parts[0].decode("utf-8"), parts[1].decode("utf-8"), parts[2]
