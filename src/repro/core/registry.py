"""Container/artifact registry (paper Sec. V).

Hosts all versions of each artifact lineage plus **one CDMT index per
lineage** (maintained with node-copying as new versions are pushed).  The
registry never re-chunks on push — the client ships chunk fps + new chunks +
the new CDMT leaf sequence; the registry rebuilds/extends the versioned index
(cheap: Fig. 10 shows indexing ≪ hashing) and verifies the root matches the
client's claim, which doubles as the authentication mechanism.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from . import hashing
from .cdmt import CDMT, CDMTParams, DEFAULT_PARAMS
from .store import DedupStore, Recipe
from .versioning import VersionedCDMT, VersionRecord


class PushRejected(ValueError):
    """Push failed server-side verification (root mismatch / bad chunk)."""


@dataclasses.dataclass
class PushReceipt:
    lineage: str
    tag: str
    version: int
    chunks_received: int
    bytes_received: int
    index_bytes: int
    root: bytes


class Registry:
    """A registry: global chunk store + per-lineage versioned CDMT."""

    def __init__(self, directory: Optional[str] = None,
                 cdmt_params: CDMTParams = DEFAULT_PARAMS):
        self.store = DedupStore(directory)
        self.cdmt_params = cdmt_params
        self.lineages: Dict[str, VersionedCDMT] = {}
        self.recipes: Dict[Tuple[str, str], Recipe] = {}   # (lineage, tag)
        self.metadata: Dict[Tuple[str, str], bytes] = {}   # small blobs (manifests)

    # -- server-side API (what the wire protocol calls) -----------------------

    def lineage(self, name: str) -> VersionedCDMT:
        if name not in self.lineages:
            self.lineages[name] = VersionedCDMT(params=self.cdmt_params)
        return self.lineages[name]

    def latest_index(self, lineage: str) -> Optional[CDMT]:
        lin = self.lineages.get(lineage)
        if lin is None or not lin.roots:
            return None
        return lin.get_version(lin.roots[-1].version)

    def index_for_tag(self, lineage: str, tag: str) -> CDMT:
        return self.lineage(lineage).get_tag(tag)

    def has_chunks(self, fps: Iterable[bytes]) -> List[bytes]:
        """Which of ``fps`` the registry is missing."""
        return self.store.missing(fps)

    def receive_push(self, lineage: str, tag: str, recipe: Recipe,
                     chunks: Dict[bytes, bytes],
                     parent_version: Optional[int] = None,
                     claimed_root: Optional[bytes] = None,
                     claimed_params: Optional[CDMTParams] = None,
                     chunks_verified: bool = False) -> PushReceipt:
        """Accept a push: verify, store new chunks, extend the versioned CDMT.

        Verification (paper Sec. V — the root check doubles as the
        authentication mechanism):

        * every pushed chunk's blake2b must equal its claimed fingerprint
          (skipped with ``chunks_verified`` — the wire frontend already
          hashes every payload during ``decode_chunk_batch``);
        * every fingerprint the recipe references must be covered — either
          pushed now or already stored — so a committed version is always
          reconstructable, and every pushed chunk must be referenced by the
          recipe, so no unreachable data enters the store;
        * with ``claimed_root`` given, the CDMT rebuilt from the recipe's
          leaf sequence must hash to exactly that root.  The rebuild uses
          ``claimed_params`` (the tree parameters the client built with —
          carried in the push header on the wire path) so clients with
          non-default ``CDMTParams`` verify correctly; the check binds the
          stored recipe to the root the client vouched for.

        All checks run *before* any state is mutated (the verification tree
        uses a throwaway node store); a failed push leaves the registry
        untouched and raises :class:`PushRejected`.
        """
        if not chunks_verified:
            for fp, data in chunks.items():
                if hashing.chunk_fingerprint(data) != fp:
                    raise PushRejected(
                        f"push {lineage}:{tag}: chunk {fp.hex()[:12]} payload "
                        f"does not hash to its fingerprint")
        referenced = set(recipe.fps)
        stray = [fp for fp in chunks if fp not in referenced]
        if stray:
            raise PushRejected(
                f"push {lineage}:{tag}: {len(stray)} pushed chunk(s) not "
                f"referenced by the recipe (first: {stray[0].hex()[:12]}) — "
                f"refusing to store unreachable data")
        unavailable = [fp for fp in self.store.missing(recipe.fps)
                       if fp not in chunks]
        if unavailable:
            raise PushRejected(
                f"push {lineage}:{tag}: recipe references "
                f"{len(unavailable)} chunk(s) neither pushed nor stored "
                f"(first: {unavailable[0].hex()[:12]})")
        rebuilt: Optional[CDMT] = None
        if claimed_root is not None:
            params = claimed_params or self.cdmt_params
            rebuilt = CDMT.build(recipe.fps, params=params)
            if rebuilt.root != claimed_root:
                raise PushRejected(
                    f"push {lineage}:{tag}: rebuilt CDMT root "
                    f"{rebuilt.root.hex()[:12] if rebuilt.root else None} != "
                    f"claimed {claimed_root.hex()[:12]}")
            if params != self.cdmt_params:
                rebuilt = None          # cannot donate a differently-cut tree
        lin = self.lineage(lineage)
        nbytes = 0
        nchunks = 0
        for fp, data in chunks.items():
            if self.store.chunks.put(fp, data):
                nchunks += 1
                nbytes += len(data)
        self.recipes[(lineage, tag)] = recipe
        self.store.recipes[f"{lineage}:{tag}"] = recipe
        rec = lin.commit(recipe.fps, tag=tag, parent=parent_version,
                         tree=rebuilt)
        idx = lin.get_version(rec.version)
        return PushReceipt(lineage=lineage, tag=tag, version=rec.version,
                           chunks_received=nchunks, bytes_received=nbytes,
                           index_bytes=idx.index_size_bytes(), root=rec.root)

    def serve_chunks(self, fps: Sequence[bytes]) -> Dict[bytes, bytes]:
        return {fp: self.store.chunks.get(fp) for fp in fps}

    def recipe_for(self, lineage: str, tag: str) -> Recipe:
        return self.recipes[(lineage, tag)]

    def tags(self, lineage: str) -> List[str]:
        lin = self.lineages.get(lineage)
        return [r.tag for r in lin.roots] if lin else []

    # -- small metadata blobs (checkpoint manifests etc.) ---------------------

    def put_metadata(self, lineage: str, tag: str, blob: bytes) -> None:
        self.metadata[(lineage, tag)] = blob

    def get_metadata(self, lineage: str, tag: str) -> bytes:
        return self.metadata[(lineage, tag)]
