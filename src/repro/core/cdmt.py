"""Content-Defined Merkle Tree (CDMT) — the paper's core contribution (Sec. IV).

A Merkle tree whose *internal-node* boundaries are content-defined, exactly as
CDC makes *chunk* boundaries content-defined.  Building a level, children are
appended to the open parent one at a time; after the parent holds at least
``window`` children, a rolling hash over the **last ``window`` child
fingerprints** is tested against a pattern rule (low ``rule_bits`` bits zero).
On a match the parent is "cut" (closed) — so parent extents are functions of
child *content*, not child *position*, and a chunk split/merge only perturbs
the O(height) path above the edit (Fig. 3).

Node identifiers remain Merkle-style — blake2b over the concatenation of ALL
child fingerprints — so the authentication-path property (Sec. III-B) and
content-addressed node sharing both hold.

Implements:
  * Algorithm 1 (build)  — ``CDMT.build``          O(N) expected
  * Algorithm 2 (compare) — ``compare`` / ``diff_chunks``  BFS with pruning
  * authentication paths over the variable-fanout structure
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from . import hashing


@dataclasses.dataclass(frozen=True)
class CDMTParams:
    window: int = 8          # rolling window of child fingerprints (paper: 8)
    rule_bits: int = 2       # boundary rule: low bits zero (paper: ~1/4 fanout)
    max_fanout: int = 64     # hard cap so adversarial content can't flatten the tree

    @property
    def rule_mask(self) -> int:
        return (1 << self.rule_bits) - 1


DEFAULT_PARAMS = CDMTParams()


@dataclasses.dataclass
class CDMTNode:
    fp: bytes
    children: Tuple[bytes, ...]     # () for leaves
    is_leaf: bool
    n_leaves: int                   # leaves under this node (for accounting)


def _window_matches(children: Sequence[bytes], params: CDMTParams) -> bool:
    """Rolling-window boundary test: blake2b over the last ``window`` child
    fps, low ``rule_bits`` bits zero.  Uses full blake2b (not a weaker rolling
    poly) because the window is tiny — ≤ window × 16 bytes per test."""
    w = children[-params.window:]
    h = hashing.node_fingerprint(w)
    return (h[-1] & params.rule_mask) == 0


class CDMT:
    """The CDMT index for one artifact version."""

    def __init__(self, params: CDMTParams = DEFAULT_PARAMS):
        self.params = params
        self.nodes: Dict[bytes, CDMTNode] = {}
        self.root: Optional[bytes] = None
        self.levels: List[List[bytes]] = []

    # ------------------------------------------------------------------ build

    @classmethod
    def build(cls, leaf_fps: Sequence[bytes], params: CDMTParams = DEFAULT_PARAMS,
              node_store: Optional[Dict[bytes, CDMTNode]] = None) -> "CDMT":
        """Algorithm 1.  ``node_store`` (the hashmap ``hm`` of the paper) lets
        multiple versions share node objects — node-copying persistence falls
        out of content addressing: only nodes on changed paths are new."""
        t = cls(params=params)
        hm = node_store if node_store is not None else t.nodes
        if not leaf_fps:
            return t

        level: List[bytes] = []
        for fp in leaf_fps:                       # lines 4–10: insert leaves
            if fp not in hm:
                hm[fp] = CDMTNode(fp=fp, children=(), is_leaf=True, n_leaves=1)
            t.nodes[fp] = hm[fp]
            level.append(fp)
        t.levels.append(list(level))

        while len(level) > 1:                     # lines 12–28: level passes
            nxt: List[bytes] = []
            open_children: List[bytes] = []
            for i, child in enumerate(level):
                open_children.append(child)       # line 14–15: extend window
                is_last = i == len(level) - 1
                cut = False
                if len(open_children) >= params.window:
                    cut = _window_matches(open_children, params)   # line 17
                if len(open_children) >= params.max_fanout:
                    cut = True
                if cut or is_last:                # line 18 / lines 23–24
                    kids = tuple(open_children)
                    fp = hashing.node_fingerprint(kids)
                    if fp not in hm:
                        hm[fp] = CDMTNode(
                            fp=fp, children=kids, is_leaf=False,
                            n_leaves=sum(hm[c].n_leaves for c in kids))
                    t.nodes[fp] = hm[fp]
                    nxt.append(fp)
                    open_children = []
            # share subtree nodes into the version-local map
            t.levels.append(list(nxt))
            level = nxt
        t.root = level[0]
        # pull every reachable node into t.nodes (shared from hm)
        if node_store is not None:
            stack = [t.root]
            while stack:
                fp = stack.pop()
                if fp in t.nodes:
                    node = t.nodes[fp]
                else:
                    node = hm[fp]
                    t.nodes[fp] = node
                stack.extend(c for c in node.children if c not in t.nodes)
        return t

    # ---------------------------------------------------------------- queries

    def node_set(self) -> Set[bytes]:
        return set(self.nodes.keys())

    def leaf_fps(self) -> List[bytes]:
        return list(self.levels[0]) if self.levels else []

    def height(self) -> int:
        return len(self.levels)

    def n_nodes(self) -> int:
        return len(self.nodes)

    def index_size_bytes(self) -> int:
        """Serialized index footprint (the paper: "~KBs")."""
        total = 0
        for n in self.nodes.values():
            total += len(n.fp) + sum(len(c) for c in n.children) + 2
        return total

    def authentication_path(self, leaf_fp: bytes) -> List[bytes]:
        """Sibling fps of every node on the path from ``leaf_fp`` to root."""
        # parent map (variable fanout ⇒ walk levels)
        parent: Dict[bytes, bytes] = {}
        for lvl in self.levels[1:]:
            for pfp in lvl:
                for c in self.nodes[pfp].children:
                    parent[c] = pfp
        path: List[bytes] = []
        cur = leaf_fp
        while cur != self.root:
            p = parent[cur]
            path.extend(c for c in self.nodes[p].children if c != cur)
            cur = p
        return path


# -------------------------------------------------------------------- compare

def iter_missing_leaves(client: Optional[CDMT], server: CDMT,
                        on_compare=None):
    """Streaming Algorithm 2 — BFS over the server tree, pruning subtrees
    whose node id the client already has, yielding missing leaf fps *as the
    walk discovers them* (deduplicated) so transfer can overlap comparison.

    ``on_compare`` is invoked once per node comparison (accounting hook).
    With ``client=None`` (fresh pull of a new image) every leaf is missing
    and zero comparisons are needed — the paper's "push of a new image" case.
    """
    if server.root is None:
        return
    yielded: Set[bytes] = set()
    if client is None:
        for fp in server.leaf_fps():
            if fp not in yielded:
                yielded.add(fp)
                yield fp
        return
    have = client.node_set()
    queue: "deque[bytes]" = deque([server.root])
    while queue:                                    # lines 3–11
        fp = queue.popleft()
        if on_compare is not None:
            on_compare()
        if fp in have:                              # subtree shared: prune
            continue
        node = server.nodes[fp]
        if node.children:                           # line 5–6: descend
            queue.extend(node.children)
        elif fp not in yielded:                     # line 8: yield leaf
            yielded.add(fp)
            yield fp


def compare(client: Optional[CDMT], server: CDMT) -> Tuple[Set[bytes], int]:
    """Algorithm 2 — returns (leaf fps the client is MISSING, number of node
    comparisons performed).  Set-materialized form of
    :func:`iter_missing_leaves` (the single BFS implementation)."""
    comparisons = [0]

    def tick():
        comparisons[0] += 1

    missing = set(iter_missing_leaves(client, server, on_compare=tick))
    return missing, comparisons[0]


def diff_chunks(old: Optional[CDMT], new: CDMT) -> Set[bytes]:
    """Leaf fingerprints present in ``new`` but not detectable via ``old``."""
    return compare(old, new)[0]


def common_node_ratio(a: CDMT, b: CDMT) -> float:
    """|shared node ids| / |nodes of b| — CDMT side of Fig. 8."""
    if not b.nodes:
        return 1.0
    return len(a.node_set() & b.node_set()) / len(b.nodes)


def comparison_ratio(client: CDMT, server: CDMT) -> float:
    """Fig. 9 metric: comparisons via CDMT ÷ comparisons via flat key-value
    lookup (= number of server leaves).  < 1 ⇒ authentication-path pruning
    is saving work."""
    n_leaves = len(server.leaf_fps())
    if n_leaves == 0:
        return 0.0
    _, comps = compare(client, server)
    return comps / n_leaves
