"""Content-Defined Merkle Tree (CDMT) — the paper's core contribution (Sec. IV).

A Merkle tree whose *internal-node* boundaries are content-defined, exactly as
CDC makes *chunk* boundaries content-defined.  Building a level, children are
appended to the open parent one at a time; after the parent holds at least
``window`` children, a rolling hash over the **last ``window`` child
fingerprints** is tested against a pattern rule (low ``rule_bits`` bits zero).
On a match the parent is "cut" (closed) — so parent extents are functions of
child *content*, not child *position*, and a chunk split/merge only perturbs
the O(height) path above the edit (Fig. 3).

Node identifiers remain Merkle-style — blake2b over the concatenation of ALL
child fingerprints — so the authentication-path property (Sec. III-B) and
content-addressed node sharing both hold.

Implements:
  * Algorithm 1 (build)  — ``CDMT.build``          O(N) expected
  * Algorithm 2 (compare) — ``compare`` / ``diff_chunks``  BFS with pruning
  * authentication paths over the variable-fanout structure
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from . import hashing


@dataclasses.dataclass(frozen=True)
class CDMTParams:
    window: int = 8          # rolling window of child fingerprints (paper: 8)
    rule_bits: int = 2       # boundary rule: low bits zero (paper: ~1/4 fanout)
    max_fanout: int = 64     # hard cap so adversarial content can't flatten the tree

    @property
    def rule_mask(self) -> int:
        return (1 << self.rule_bits) - 1


DEFAULT_PARAMS = CDMTParams()


@dataclasses.dataclass
class CDMTNode:
    fp: bytes
    children: Tuple[bytes, ...]     # () for leaves
    is_leaf: bool
    n_leaves: int                   # leaves under this node (for accounting)


@dataclasses.dataclass
class BuildStats:
    """Work accounting for one build: the paper's "indexing ≪ hashing" and
    the incremental path's O(changed-subtrees) claim are both statements
    about how many blake2b calls a push costs."""
    nodes_hashed: int = 0           # node-id fingerprints computed
    boundary_tests: int = 0         # rolling-window cut tests (also blake2b)
    nodes_created: int = 0          # nodes newly added to the store

    @property
    def hash_calls(self) -> int:
        return self.nodes_hashed + self.boundary_tests


class OverlayNodeStore:
    """Copy-on-write view over a base node store.

    Reads fall through to ``base``; writes land only in ``overlay``.  Lets a
    registry *verify* a push by building the claimed tree against the shared
    store without mutating it — on success the overlay (exactly the new
    nodes) is merged, on rejection it is dropped and the store is untouched.
    """

    __slots__ = ("base", "overlay")

    def __init__(self, base: Dict[bytes, CDMTNode]):
        self.base = base
        self.overlay: Dict[bytes, CDMTNode] = {}

    def __contains__(self, fp: bytes) -> bool:
        return fp in self.overlay or fp in self.base

    def __getitem__(self, fp: bytes) -> CDMTNode:
        node = self.overlay.get(fp)
        if node is not None:
            return node
        return self.base[fp]

    def __setitem__(self, fp: bytes, node: CDMTNode) -> None:
        if fp not in self.base:
            self.overlay[fp] = node

    def get(self, fp: bytes, default=None):
        node = self.overlay.get(fp)
        if node is not None:
            return node
        return self.base.get(fp, default)


def _window_matches(children: Sequence[bytes], params: CDMTParams) -> bool:
    """Rolling-window boundary test: blake2b over the last ``window`` child
    fps, low ``rule_bits`` bits zero.  Uses full blake2b (not a weaker rolling
    poly) because the window is tiny — ≤ window × 16 bytes per test."""
    w = children[-params.window:]
    h = hashing.node_fingerprint(w)
    return (h[-1] & params.rule_mask) == 0


def _make_parent(kids: Tuple[bytes, ...], hm, stats: Optional[BuildStats],
                 fallback: Optional[Dict[bytes, CDMTNode]] = None) -> bytes:
    """Close a parent over ``kids``: hash its id, intern it in the store.
    ``fallback`` resolves children reused from a parent tree that are not
    (yet) in ``hm`` — the incremental path's shared subtrees."""
    fp = hashing.node_fingerprint(kids)
    if stats is not None:
        stats.nodes_hashed += 1
    if fp not in hm:
        def _n_leaves(c: bytes) -> int:
            node = hm.get(c)
            if node is None and fallback is not None:
                node = fallback[c]
            return node.n_leaves
        hm[fp] = CDMTNode(fp=fp, children=kids, is_leaf=False,
                          n_leaves=sum(_n_leaves(c) for c in kids))
        if stats is not None:
            stats.nodes_created += 1
    return fp


def _build_level(children: Sequence[bytes], params: CDMTParams, hm,
                 stats: Optional[BuildStats],
                 fallback: Optional[Dict[bytes, CDMTNode]] = None
                 ) -> List[bytes]:
    """One full level pass of Algorithm 1 (lines 12–28)."""
    out: List[bytes] = []
    open_children: List[bytes] = []
    for i, child in enumerate(children):
        open_children.append(child)               # line 14–15: extend window
        is_last = i == len(children) - 1
        cut = False
        if len(open_children) >= params.window:
            if stats is not None:
                stats.boundary_tests += 1
            cut = _window_matches(open_children, params)       # line 17
        if len(open_children) >= params.max_fanout:
            cut = True
        if cut or is_last:                        # line 18 / lines 23–24
            out.append(_make_parent(tuple(open_children), hm, stats,
                                    fallback=fallback))
            open_children = []
    return out


class CDMT:
    """The CDMT index for one artifact version."""

    def __init__(self, params: CDMTParams = DEFAULT_PARAMS):
        self.params = params
        self.nodes: Dict[bytes, CDMTNode] = {}
        self.root: Optional[bytes] = None
        self.levels: List[List[bytes]] = []

    # ------------------------------------------------------------------ build

    @classmethod
    def build(cls, leaf_fps: Sequence[bytes], params: CDMTParams = DEFAULT_PARAMS,
              node_store: Optional[Dict[bytes, CDMTNode]] = None,
              stats: Optional[BuildStats] = None) -> "CDMT":
        """Algorithm 1.  ``node_store`` (the hashmap ``hm`` of the paper) lets
        multiple versions share node objects — node-copying persistence falls
        out of content addressing: only nodes on changed paths are new."""
        t = cls(params=params)
        hm = node_store if node_store is not None else t.nodes
        if not leaf_fps:
            return t

        level: List[bytes] = []
        for fp in leaf_fps:                       # lines 4–10: insert leaves
            if fp not in hm:
                hm[fp] = CDMTNode(fp=fp, children=(), is_leaf=True, n_leaves=1)
                if stats is not None:
                    stats.nodes_created += 1
            t.nodes[fp] = hm[fp]
            level.append(fp)
        t.levels.append(list(level))

        while len(level) > 1:                     # lines 12–28: level passes
            level = _build_level(level, params, hm, stats)
            t.levels.append(list(level))
        t.root = level[0]
        t._adopt_reachable(hm)
        return t

    @classmethod
    def build_incremental(cls, parent: "CDMT", leaf_fps: Sequence[bytes],
                          params: Optional[CDMTParams] = None,
                          node_store: Optional[Dict[bytes, CDMTNode]] = None,
                          stats: Optional[BuildStats] = None) -> "CDMT":
        """Incremental Algorithm 1: reuse the parent version's unchanged
        content-defined subtrees, re-hashing only spans whose leaves changed.

        Because the cut rule is a deterministic function of (params, child
        sequence) alone, the result is **bit-identical** to
        ``CDMT.build(leaf_fps, params)`` — same levels, same root — while
        computing only O(k · depth · fanout) fingerprints for k changed
        leaves: per level, parents whose child spans lie in the unchanged
        prefix are reused directly; the edited span is re-cut; and as soon as
        a new cut lands on an old parent boundary inside the unchanged
        suffix, the build resynchronizes and reuses every remaining parent
        (the content-defined analogue of CDC's bounded chunk-shift, Fig. 3).

        Falls back to a full build when the parent is empty or was built
        with different params (its cut structure is incompatible).
        """
        if params is None:
            params = parent.params
        if parent.root is None or parent.params != params or not leaf_fps:
            return cls.build(leaf_fps, params=params, node_store=node_store,
                             stats=stats)
        t = cls(params=params)
        hm = node_store if node_store is not None else t.nodes

        level: List[bytes] = []
        for fp in leaf_fps:
            if fp not in hm:
                hm[fp] = CDMTNode(fp=fp, children=(), is_leaf=True, n_leaves=1)
                if stats is not None:
                    stats.nodes_created += 1
            level.append(fp)
        t.levels.append(list(level))

        li = 0
        while len(level) > 1:
            old_parents = (parent.levels[li + 1]
                           if li + 1 < len(parent.levels) else [])
            level = _rebuild_level(old_parents, level, params,
                                   hm, parent.nodes, stats)
            t.levels.append(list(level))
            li += 1
        t.root = level[0]
        t._adopt_reachable(hm, fallback=parent.nodes)
        return t

    def _adopt_reachable(self, hm,
                         fallback: Optional[Dict[bytes, CDMTNode]] = None
                         ) -> None:
        """Pull every node reachable from the root into ``self.nodes``
        (shared from ``hm``, or from ``fallback`` for subtrees reused from a
        parent tree) — pointer chasing only, no hashing."""
        if self.root is None or (hm is self.nodes and fallback is None):
            return
        stack = [self.root]
        seen: Set[bytes] = set()
        while stack:
            fp = stack.pop()
            if fp in seen:
                continue
            seen.add(fp)
            node = self.nodes.get(fp) or hm.get(fp)
            if node is None and fallback is not None:
                node = fallback[fp]
            self.nodes[fp] = node
            stack.extend(c for c in node.children if c not in seen)

    # ---------------------------------------------------------------- queries

    def node_set(self) -> Set[bytes]:
        return set(self.nodes.keys())

    def leaf_fps(self) -> List[bytes]:
        return list(self.levels[0]) if self.levels else []

    def height(self) -> int:
        return len(self.levels)

    def n_nodes(self) -> int:
        return len(self.nodes)

    def index_size_bytes(self) -> int:
        """Serialized index footprint (the paper: "~KBs")."""
        total = 0
        for n in self.nodes.values():
            total += len(n.fp) + sum(len(c) for c in n.children) + 2
        return total

    def authentication_path(self, leaf_fp: bytes) -> List[bytes]:
        """Sibling fps of every node on the path from ``leaf_fp`` to root."""
        # parent map (variable fanout ⇒ walk levels)
        parent: Dict[bytes, bytes] = {}
        for lvl in self.levels[1:]:
            for pfp in lvl:
                for c in self.nodes[pfp].children:
                    parent[c] = pfp
        path: List[bytes] = []
        cur = leaf_fp
        while cur != self.root:
            p = parent[cur]
            path.extend(c for c in self.nodes[p].children if c != cur)
            cur = p
        return path


_MAX_REUSE_CANDIDATES = 8     # bound probing under degenerate duplicate content


def _rebuild_level(old_parents: Sequence[bytes],
                   new_children: Sequence[bytes],
                   params: CDMTParams, hm,
                   parent_nodes: Dict[bytes, CDMTNode],
                   stats: Optional[BuildStats]) -> List[bytes]:
    """One level of the incremental build.

    Correctness rests on one property of the cut rule: a cut decision
    depends only on the children of the *currently open* parent (the rolling
    window never crosses a cut, and ``max_fanout`` counts from the parent
    start).  So whenever the build stands at a fresh parent start and the
    upcoming children exactly equal some old parent's child sequence, the
    full build would reproduce that parent verbatim — no early cut inside it
    (the same window tests failed when the old level was built) and the same
    close at its end — provided the old close was itself content-defined.
    Old parents that were not the last of their level necessarily closed on
    a cut, so only reuse of a level's *final* parent needs a window re-test.

    This is position-independent, so the build resynchronizes right after
    every edited span (not just around a single edit): k scattered leaf
    changes cost O(k · fanout) fingerprints per level, while unchanged runs
    cost only cheap sequence comparisons.
    """
    if not old_parents:
        return _build_level(new_children, params, hm, stats,
                            fallback=parent_nodes)
    n_new = len(new_children)

    # reuse candidates: first-child fp -> [(old parent fp, children, interior)]
    cand: Dict[bytes, List[Tuple[bytes, Tuple[bytes, ...], bool]]] = {}
    seen_kids: Set[Tuple[bytes, ...]] = set()
    last = len(old_parents) - 1
    for i, pfp in enumerate(old_parents):
        node = parent_nodes.get(pfp)
        if node is None:
            node = hm[pfp]
        kids = node.children
        if kids and kids not in seen_kids:
            seen_kids.add(kids)
            lst = cand.setdefault(kids[0], [])
            if len(lst) < _MAX_REUSE_CANDIDATES:
                lst.append((pfp, kids, i < last))

    out: List[bytes] = []
    open_children: List[bytes] = []
    j = 0
    while j < n_new:
        if not open_children:                      # at a fresh parent start
            reused = None
            for pfp, kids, interior in cand.get(new_children[j], ()):
                w = len(kids)
                if tuple(new_children[j:j + w]) != kids:
                    continue
                if j + w < n_new and not interior:
                    # old level's final parent: closed by end-of-level, which
                    # recurs here only if the close was also a content cut
                    cut = w >= params.max_fanout
                    if not cut and w >= params.window:
                        if stats is not None:
                            stats.boundary_tests += 1
                        cut = _window_matches(kids, params)
                    if not cut:
                        continue
                reused = (pfp, w)
                break
            if reused is not None:
                out.append(reused[0])
                j += reused[1]
                continue
        open_children.append(new_children[j])
        is_last = j == n_new - 1
        cut = False
        if len(open_children) >= params.window:
            if stats is not None:
                stats.boundary_tests += 1
            cut = _window_matches(open_children, params)
        if len(open_children) >= params.max_fanout:
            cut = True
        if cut or is_last:
            out.append(_make_parent(tuple(open_children), hm, stats,
                                    fallback=parent_nodes))
            open_children = []
        j += 1
    return out


# -------------------------------------------------------------------- compare

def iter_missing_leaves(client: Optional[CDMT], server: CDMT,
                        on_compare=None):
    """Streaming Algorithm 2 — BFS over the server tree, pruning subtrees
    whose node id the client already has, yielding missing leaf fps *as the
    walk discovers them* (deduplicated) so transfer can overlap comparison.

    ``on_compare`` is invoked once per node comparison (accounting hook).
    With ``client=None`` (fresh pull of a new image) every leaf is missing
    and zero comparisons are needed — the paper's "push of a new image" case.
    """
    if server.root is None:
        return
    yielded: Set[bytes] = set()
    if client is None:
        for fp in server.leaf_fps():
            if fp not in yielded:
                yielded.add(fp)
                yield fp
        return
    have = client.node_set()
    queue: "deque[bytes]" = deque([server.root])
    while queue:                                    # lines 3–11
        fp = queue.popleft()
        if on_compare is not None:
            on_compare()
        if fp in have:                              # subtree shared: prune
            continue
        node = server.nodes[fp]
        if node.children:                           # line 5–6: descend
            queue.extend(node.children)
        elif fp not in yielded:                     # line 8: yield leaf
            yielded.add(fp)
            yield fp


def compare(client: Optional[CDMT], server: CDMT) -> Tuple[Set[bytes], int]:
    """Algorithm 2 — returns (leaf fps the client is MISSING, number of node
    comparisons performed).  Set-materialized form of
    :func:`iter_missing_leaves` (the single BFS implementation)."""
    comparisons = [0]

    def tick():
        comparisons[0] += 1

    missing = set(iter_missing_leaves(client, server, on_compare=tick))
    return missing, comparisons[0]


def diff_chunks(old: Optional[CDMT], new: CDMT) -> Set[bytes]:
    """Leaf fingerprints present in ``new`` but not detectable via ``old``."""
    return compare(old, new)[0]


def common_node_ratio(a: CDMT, b: CDMT) -> float:
    """|shared node ids| / |nodes of b| — CDMT side of Fig. 8."""
    if not b.nodes:
        return 1.0
    return len(a.node_set() & b.node_set()) / len(b.nodes)


def comparison_ratio(client: CDMT, server: CDMT) -> float:
    """Fig. 9 metric: comparisons via CDMT ÷ comparisons via flat key-value
    lookup (= number of server leaves).  < 1 ⇒ authentication-path pruning
    is saving work."""
    n_leaves = len(server.leaf_fps())
    if n_leaves == 0:
        return 0.0
    _, comps = compare(client, server)
    return comps / n_leaves
