"""Deduplicated storage — the paper's three-component prototype (Sec. V):

  (i)   **container store**  — unique CDC chunks in log-structured storage,
  (ii)  **fingerprint index** — fp → physical location (here, the CDMT serves
        as the *comparison* index; the flat map is the location index),
  (iii) **recipe store**     — per-artifact ordered fp list for reconstruction.

Backed either by memory (tests/benchmarks) or a directory (examples /
checkpointing).  All writes are append-only; chunks are immutable.

Crash safety (directory mode): ``chunks.log`` is written before its
``chunks.idx`` entry, so recovery (:meth:`ChunkStore._load`) can always
repair a torn write — a partial index record is truncated, an index entry
pointing past the end of the log is dropped (with everything after it), and
an orphan log tail with no index entry is truncated.  ``sync()`` fsyncs both
files and then atomically updates a ``chunks.clean`` marker recording the
synced sizes; on recovery, entries within the marker are trusted, while
entries written *after* the last sync are verified against their payload's
blake2b (the OS may persist an index entry and the log's length without the
log's data blocks — a flush is not an fsync), with the first mismatch
treated as the torn tail.  The registry calls ``sync()`` before journaling a
commit so an acknowledged push never references non-durable chunks.
"""

from __future__ import annotations

import dataclasses
import json
import os
import struct
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from . import cdc, hashing
from .errors import DeliveryError
from .journal import fsync_dir


@dataclasses.dataclass
class Recipe:
    """Ordered fingerprint sequence reconstructing one artifact (layer)."""
    name: str
    fps: List[bytes]
    sizes: List[int]

    @property
    def total_size(self) -> int:
        return sum(self.sizes)

    def to_json(self) -> str:
        return json.dumps({
            "name": self.name,
            "fps": [f.hex() for f in self.fps],
            "sizes": self.sizes,
        })

    @classmethod
    def from_json(cls, s: str) -> "Recipe":
        """Parse + validate: a malformed recipe must fail here with a clear
        ``ValueError``, not later as an opaque KeyError/size mismatch."""
        d = json.loads(s)
        name = d["name"]
        fps = [bytes.fromhex(f) for f in d["fps"]]
        sizes = [int(x) for x in d["sizes"]]
        if len(fps) != len(sizes):
            raise ValueError(
                f"recipe {name!r}: {len(fps)} fingerprints but "
                f"{len(sizes)} sizes")
        for f in fps:
            if len(f) != hashing.DIGEST_SIZE:
                raise ValueError(
                    f"recipe {name!r}: fingerprint length {len(f)} != "
                    f"digest size {hashing.DIGEST_SIZE}")
        if any(x < 0 for x in sizes):
            raise ValueError(f"recipe {name!r}: negative chunk size")
        return cls(name=name, fps=fps, sizes=sizes)


class ChunkStore:
    """Log-structured unique-chunk store with a fingerprint→location index."""

    _IDX_ENTRY = hashing.DIGEST_SIZE + 16       # fp + <QQ>(offset, size)

    def __init__(self, directory: Optional[str] = None):
        self.directory = directory
        self._mem: Dict[bytes, bytes] = {}
        self._index: Dict[bytes, Tuple[int, int]] = {}   # fp -> (offset, size)
        self._log_path: Optional[str] = None
        self._idx_path: Optional[str] = None
        self._clean_path: Optional[str] = None
        self._flag_path: Optional[str] = None
        self._log_size = 0
        self._idx_size = 0
        self._log_f = None
        self._idx_f = None
        self._read_fd: Optional[int] = None
        self.recovered_torn_bytes = 0           # crash debris dropped at open
        if directory is not None:
            os.makedirs(directory, exist_ok=True)
            self._log_path = os.path.join(directory, "chunks.log")
            self._idx_path = os.path.join(directory, "chunks.idx")
            self._clean_path = os.path.join(directory, "chunks.clean")
            self._flag_path = os.path.join(directory, "chunks.compacting")
            self._finish_compaction()
            self._load()
            # persistent handles: append once, not reopen-per-put; reads use
            # pread on a dedicated fd (positionless ⇒ thread-safe)
            self._log_f = open(self._log_path, "ab")
            self._idx_f = open(self._idx_path, "ab")
            self._read_fd = os.open(self._log_path, os.O_RDONLY)

    # -- persistence ---------------------------------------------------------

    def _finish_compaction(self) -> None:
        """Recover from a crash during :meth:`compact`.

        Compaction writes fully-fsynced ``.new`` log/idx files, then commits
        by creating ``chunks.compacting`` (the durable intent), then swaps
        each ``.new`` file into place.  Recovery is therefore idempotent:
        without the flag, leftover ``.new`` files are an uncommitted
        compaction and are discarded; with the flag, any ``.new`` file still
        present is swapped in, the (stale) clean marker is dropped so
        ``_load`` re-verifies payloads, and the flag is removed."""
        new_log = self._log_path + ".new"
        new_idx = self._idx_path + ".new"
        if not os.path.exists(self._flag_path):
            for path in (new_log, new_idx):
                if os.path.exists(path):
                    os.unlink(path)
            return
        for src, dst in ((new_log, self._log_path), (new_idx, self._idx_path)):
            if os.path.exists(src):
                os.replace(src, dst)  # durability-ok: .new files were fsynced before the durable intent flag landed; recovery only completes the rename
        fsync_dir(self.directory)
        if os.path.exists(self._clean_path):
            os.unlink(self._clean_path)    # sized for the pre-compaction files
        os.unlink(self._flag_path)

    def _read_marker(self) -> Tuple[int, int]:
        """(log bytes, idx bytes) known durable from the last ``sync()``."""
        try:
            with open(self._clean_path, "rb") as f:
                raw = f.read(16)
            if len(raw) == 16:
                return struct.unpack("<QQ", raw)
        except OSError:
            pass
        return 0, 0

    def _load(self) -> None:
        """Rebuild the in-memory index, repairing any torn tail.  Entries
        past the ``chunks.clean`` marker (written after the last fsync) are
        verified against their payload hash: an fsync-less crash can persist
        the index entry and the log length without the log's data blocks."""
        log_size = (os.path.getsize(self._log_path)
                    if os.path.exists(self._log_path) else 0)
        data = b""
        if os.path.exists(self._idx_path):
            with open(self._idx_path, "rb") as f:
                data = f.read()
        trusted_log, trusted_idx = self._read_marker()
        log_f = open(self._log_path, "rb") if log_size else None
        good = 0
        end = 0
        off = 0
        try:
            while off + self._IDX_ENTRY <= len(data):
                fp = data[off:off + hashing.DIGEST_SIZE]
                o, s = struct.unpack_from("<QQ", data,
                                          off + hashing.DIGEST_SIZE)
                if o + s > log_size:
                    break   # entry references bytes the log never durably got
                if off + self._IDX_ENTRY > trusted_idx or o + s > trusted_log:
                    log_f.seek(o)
                    if hashing.chunk_fingerprint(log_f.read(s)) != fp:
                        break                   # unsynced data never landed
                self._index[fp] = (o, s)
                end = max(end, o + s)
                off += self._IDX_ENTRY
                good = off
        finally:
            if log_f is not None:
                log_f.close()
        if len(data) > good:                    # partial/invalid idx records
            self.recovered_torn_bytes += len(data) - good
            with open(self._idx_path, "r+b") as f:
                f.truncate(good)
        if log_size > end:                      # orphan chunk bytes, no entry
            self.recovered_torn_bytes += log_size - end
            with open(self._log_path, "r+b") as f:
                f.truncate(end)
        self._log_size = end
        self._idx_size = good

    # -- API -----------------------------------------------------------------

    def has(self, fp: bytes) -> bool:
        return fp in self._index or fp in self._mem

    def put(self, fp: bytes, data: bytes) -> bool:
        """Store chunk if absent.  Returns True if newly stored.  Log bytes
        are flushed before the index entry is written, preserving the
        log-before-index recovery invariant."""
        if self.has(fp):
            return False
        if self.directory is not None:
            if self._log_f is None:
                raise RuntimeError(
                    f"ChunkStore {self.directory} is closed — refusing to "
                    f"degrade to the in-memory backend")
            self._log_f.write(data)
            self._log_f.flush()
            self._idx_f.write(fp + struct.pack("<QQ", self._log_size, len(data)))
            self._idx_f.flush()
            self._index[fp] = (self._log_size, len(data))
            self._log_size += len(data)
            self._idx_size += self._IDX_ENTRY
        else:
            self._mem[fp] = data
            self._index[fp] = (0, len(data))
        return True

    def get(self, fp: bytes) -> bytes:
        if fp in self._mem:
            return self._mem[fp]
        if self.directory is not None and fp in self._index:
            if self._read_fd is None:
                raise RuntimeError(
                    f"ChunkStore {self.directory} is closed")
            off, size = self._index[fp]
            return os.pread(self._read_fd, size, off)
        raise KeyError(fp.hex())  # raises-ok: mapping protocol — every boundary caller wraps (Registry.serve_chunks, DedupStore restore paths)

    def sync(self) -> None:
        """fsync log then index, then atomically advance the clean marker —
        after this returns, every acknowledged ``put`` survives a host crash
        and is trusted without re-verification on the next open.  No-op for
        the memory backend."""
        if self._log_f is not None:
            self._log_f.flush()
            os.fsync(self._log_f.fileno())
            self._idx_f.flush()
            os.fsync(self._idx_f.fileno())
            self._write_marker()

    def _write_marker(self) -> None:
        tmp = self._clean_path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(struct.pack("<QQ", self._log_size, self._idx_size))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._clean_path)
        fsync_dir(self.directory)

    def compact(self, live: Iterable[bytes]) -> Tuple[int, int]:
        """Drop every chunk not in ``live`` and compact the log.

        Returns ``(dropped_chunks, reclaimed_bytes)``.  Crash-safe on the
        directory backend: live chunks are streamed into fsynced ``.new``
        log/idx files, the swap is committed by the durable
        ``chunks.compacting`` intent flag, and each rename is individually
        idempotent — :meth:`_finish_compaction` completes (or discards) a
        half-done compaction on the next open, so no crash window can mix
        old index entries with new log offsets.
        """
        live = set(live)
        dead = [fp for fp in self._index if fp not in live]
        if not dead:
            return 0, 0
        reclaimed = sum(self._index[fp][1] for fp in dead)
        if self.directory is None:
            for fp in dead:
                self._mem.pop(fp, None)
                del self._index[fp]
            return len(dead), reclaimed
        if self._log_f is None:
            raise RuntimeError(
                f"ChunkStore {self.directory} is closed — cannot compact")
        self._log_f.flush()                # stream from a settled log
        new_log_path = self._log_path + ".new"
        new_idx_path = self._idx_path + ".new"
        new_index: Dict[bytes, Tuple[int, int]] = {}
        off = 0
        with open(new_log_path, "wb") as lf, open(new_idx_path, "wb") as xf:
            # keep current log order (offset-ascending) for locality
            for fp, (o, s) in sorted(self._index.items(),
                                     key=lambda kv: kv[1][0]):
                if fp not in live:
                    continue
                lf.write(os.pread(self._read_fd, s, o))
                xf.write(fp + struct.pack("<QQ", off, s))
                new_index[fp] = (off, s)
                off += s
            lf.flush()
            os.fsync(lf.fileno())
            xf.flush()
            os.fsync(xf.fileno())
        # durable intent: from here on, recovery completes the swap
        with open(self._flag_path, "wb") as f:
            f.write(b"compact")
            f.flush()
            os.fsync(f.fileno())
        self._log_f.close()
        self._idx_f.close()
        os.close(self._read_fd)
        os.replace(new_log_path, self._log_path)
        os.replace(new_idx_path, self._idx_path)
        fsync_dir(self.directory)
        self._index = new_index
        self._log_size = off
        self._idx_size = len(new_index) * self._IDX_ENTRY
        self._write_marker()               # sized for the compacted files
        os.unlink(self._flag_path)
        self._log_f = open(self._log_path, "ab")
        self._idx_f = open(self._idx_path, "ab")
        self._read_fd = os.open(self._log_path, os.O_RDONLY)
        return len(dead), reclaimed

    def close(self) -> None:
        if self._log_f is not None:
            self.sync()
            self._log_f.close()
            self._idx_f.close()
            os.close(self._read_fd)
            self._log_f = self._idx_f = self._read_fd = None

    def chunk_size(self, fp: bytes) -> int:
        return self._index[fp][1]

    def n_chunks(self) -> int:
        return len(self._index)

    def stored_bytes(self) -> int:
        return sum(s for _, s in self._index.values())

    def fingerprints(self) -> Iterable[bytes]:
        return self._index.keys()

    def index_entries(self) -> List[Tuple[bytes, int, int]]:
        """``(fp, offset, size)`` for every stored chunk — offset ordering
        reflects append order, which restart warm-up uses as a recency
        proxy.  Offsets are 0 on the memory backend."""
        return [(fp, off, size) for fp, (off, size) in self._index.items()]


class DedupStore:
    """Client/registry-side deduplicated store: chunks + recipes + accounting."""

    def __init__(self, directory: Optional[str] = None,
                 cdc_params: cdc.CDCParams = cdc.DEFAULT_PARAMS):
        self.chunks = ChunkStore(directory)
        self.recipes: Dict[str, Recipe] = {}
        self.cdc_params = cdc_params
        # accounting
        self.ingested_bytes = 0
        self.new_chunk_bytes = 0
        self.dup_chunk_bytes = 0

    # -- ingest --------------------------------------------------------------

    def ingest(self, name: str, data: bytes) -> Recipe:
        """CDC-chunk ``data``, dedup-store new chunks, record the recipe."""
        fps: List[bytes] = []
        sizes: List[int] = []
        for chunk in cdc.chunk_bytes(data, self.cdc_params):
            fp = hashing.chunk_fingerprint(chunk)
            if self.chunks.put(fp, chunk):
                self.new_chunk_bytes += len(chunk)
            else:
                self.dup_chunk_bytes += len(chunk)
            fps.append(fp)
            sizes.append(len(chunk))
        self.ingested_bytes += len(data)
        recipe = Recipe(name=name, fps=fps, sizes=sizes)
        self.recipes[name] = recipe
        return recipe

    def ingest_chunks(self, name: str, fps: Sequence[bytes],
                      chunks: Dict[bytes, bytes],
                      sizes: Sequence[int],
                      verify: bool = True) -> Recipe:
        """Store pre-chunked data (pull path: only missing chunks provided).

        Before any mutation, coverage is checked — every fp must already be
        stored or provided in ``chunks`` — and with ``verify`` (default)
        each provided payload is hashed against its fingerprint.  A bad pull
        therefore fails *here* with a clear :class:`DeliveryError` and
        nothing half-committed, instead of surfacing later as an opaque
        ``KeyError`` in :meth:`restore`.  Callers whose transport already
        verified payloads (wire ``decode_chunk_batch`` does) pass
        ``verify=False`` to skip the second hash.
        """
        fps = list(fps)
        sizes = list(sizes)
        if len(fps) != len(sizes):
            raise DeliveryError(
                f"ingest {name}: {len(fps)} fingerprints but "
                f"{len(sizes)} sizes")
        missing = [fp for fp in fps
                   if fp not in chunks and not self.chunks.has(fp)]
        if missing:
            raise DeliveryError(
                f"ingest {name}: {len(missing)} chunk(s) neither provided "
                f"nor stored (first: {missing[0].hex()[:12]})")
        if verify:
            for fp in set(fps):
                data = chunks.get(fp)
                if data is not None and hashing.chunk_fingerprint(data) != fp:
                    raise DeliveryError(
                        f"ingest {name}: chunk {fp.hex()[:12]} payload does "
                        f"not hash to its fingerprint")
        for fp in fps:
            if fp in chunks:
                self.chunks.put(fp, chunks[fp])
        recipe = Recipe(name=name, fps=fps, sizes=sizes)
        self.recipes[name] = recipe
        return recipe

    # -- restore -------------------------------------------------------------

    def restore(self, name: str) -> bytes:
        recipe = self._recipe_for_restore(name)
        return b"".join(self._chunk_for_restore(name, fp)
                        for fp in recipe.fps)

    def restore_into(self, name: str, out: np.ndarray) -> None:
        """Zero-extra-copy restore into a preallocated uint8 buffer."""
        recipe = self._recipe_for_restore(name)
        off = 0
        for fp in recipe.fps:
            c = self._chunk_for_restore(name, fp)
            out[off:off + len(c)] = np.frombuffer(c, dtype=np.uint8)
            off += len(c)

    def _recipe_for_restore(self, name: str) -> "Recipe":
        recipe = self.recipes.get(name)
        if recipe is None:
            raise DeliveryError(f"restore: unknown recipe {name!r}")
        return recipe

    def _chunk_for_restore(self, name: str, fp: bytes) -> bytes:
        try:
            return self.chunks.get(fp)
        except KeyError:
            raise DeliveryError(
                f"restore {name}: chunk {fp.hex()[:12]} referenced by the "
                f"recipe is missing from the store") from None

    # -- accounting ----------------------------------------------------------

    def dedup_ratio(self) -> float:
        """raw ingested bytes / stored bytes (higher = better; Fig. 6/7)."""
        stored = self.chunks.stored_bytes()
        return self.ingested_bytes / stored if stored else 1.0

    def missing(self, fps: Iterable[bytes]) -> List[bytes]:
        return [fp for fp in fps if not self.chunks.has(fp)]

    def close(self) -> None:
        self.chunks.close()
