"""Deduplicated storage — the paper's three-component prototype (Sec. V):

  (i)   **container store**  — unique CDC chunks in log-structured storage,
  (ii)  **fingerprint index** — fp → physical location (here, the CDMT serves
        as the *comparison* index; the flat map is the location index),
  (iii) **recipe store**     — per-artifact ordered fp list for reconstruction.

Backed either by memory (tests/benchmarks) or a directory (examples /
checkpointing).  All writes are append-only; chunks are immutable.
"""

from __future__ import annotations

import dataclasses
import json
import os
import struct
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from . import cdc, hashing


@dataclasses.dataclass
class Recipe:
    """Ordered fingerprint sequence reconstructing one artifact (layer)."""
    name: str
    fps: List[bytes]
    sizes: List[int]

    @property
    def total_size(self) -> int:
        return sum(self.sizes)

    def to_json(self) -> str:
        return json.dumps({
            "name": self.name,
            "fps": [f.hex() for f in self.fps],
            "sizes": self.sizes,
        })

    @classmethod
    def from_json(cls, s: str) -> "Recipe":
        d = json.loads(s)
        return cls(name=d["name"], fps=[bytes.fromhex(f) for f in d["fps"]],
                   sizes=d["sizes"])


class ChunkStore:
    """Log-structured unique-chunk store with a fingerprint→location index."""

    def __init__(self, directory: Optional[str] = None):
        self.directory = directory
        self._mem: Dict[bytes, bytes] = {}
        self._index: Dict[bytes, Tuple[int, int]] = {}   # fp -> (offset, size)
        self._log_path = None
        self._log_size = 0
        if directory is not None:
            os.makedirs(directory, exist_ok=True)
            self._log_path = os.path.join(directory, "chunks.log")
            self._idx_path = os.path.join(directory, "chunks.idx")
            self._load()

    # -- persistence ---------------------------------------------------------

    def _load(self) -> None:
        if self._log_path and os.path.exists(self._idx_path):
            with open(self._idx_path, "rb") as f:
                data = f.read()
            off = 0
            while off < len(data):
                fp = data[off:off + hashing.DIGEST_SIZE]
                o, s = struct.unpack_from("<QQ", data, off + hashing.DIGEST_SIZE)
                self._index[fp] = (o, s)
                off += hashing.DIGEST_SIZE + 16
            self._log_size = os.path.getsize(self._log_path) if os.path.exists(self._log_path) else 0

    # -- API -----------------------------------------------------------------

    def has(self, fp: bytes) -> bool:
        return fp in self._index or fp in self._mem

    def put(self, fp: bytes, data: bytes) -> bool:
        """Store chunk if absent.  Returns True if newly stored."""
        if self.has(fp):
            return False
        if self._log_path is not None:
            with open(self._log_path, "ab") as f:
                f.write(data)
            with open(self._idx_path, "ab") as f:
                f.write(fp + struct.pack("<QQ", self._log_size, len(data)))
            self._index[fp] = (self._log_size, len(data))
            self._log_size += len(data)
        else:
            self._mem[fp] = data
            self._index[fp] = (0, len(data))
        return True

    def get(self, fp: bytes) -> bytes:
        if fp in self._mem:
            return self._mem[fp]
        if self._log_path is not None and fp in self._index:
            off, size = self._index[fp]
            with open(self._log_path, "rb") as f:
                f.seek(off)
                return f.read(size)
        raise KeyError(fp.hex())

    def chunk_size(self, fp: bytes) -> int:
        return self._index[fp][1]

    def n_chunks(self) -> int:
        return len(self._index)

    def stored_bytes(self) -> int:
        return sum(s for _, s in self._index.values())

    def fingerprints(self) -> Iterable[bytes]:
        return self._index.keys()


class DedupStore:
    """Client/registry-side deduplicated store: chunks + recipes + accounting."""

    def __init__(self, directory: Optional[str] = None,
                 cdc_params: cdc.CDCParams = cdc.DEFAULT_PARAMS):
        self.chunks = ChunkStore(directory)
        self.recipes: Dict[str, Recipe] = {}
        self.cdc_params = cdc_params
        # accounting
        self.ingested_bytes = 0
        self.new_chunk_bytes = 0
        self.dup_chunk_bytes = 0

    # -- ingest --------------------------------------------------------------

    def ingest(self, name: str, data: bytes) -> Recipe:
        """CDC-chunk ``data``, dedup-store new chunks, record the recipe."""
        fps: List[bytes] = []
        sizes: List[int] = []
        for chunk in cdc.chunk_bytes(data, self.cdc_params):
            fp = hashing.chunk_fingerprint(chunk)
            if self.chunks.put(fp, chunk):
                self.new_chunk_bytes += len(chunk)
            else:
                self.dup_chunk_bytes += len(chunk)
            fps.append(fp)
            sizes.append(len(chunk))
        self.ingested_bytes += len(data)
        recipe = Recipe(name=name, fps=fps, sizes=sizes)
        self.recipes[name] = recipe
        return recipe

    def ingest_chunks(self, name: str, fps: Sequence[bytes],
                      chunks: Dict[bytes, bytes],
                      sizes: Sequence[int]) -> Recipe:
        """Store pre-chunked data (pull path: only missing chunks provided)."""
        for fp in fps:
            if fp in chunks:
                self.chunks.put(fp, chunks[fp])
        recipe = Recipe(name=name, fps=list(fps), sizes=list(sizes))
        self.recipes[name] = recipe
        return recipe

    # -- restore -------------------------------------------------------------

    def restore(self, name: str) -> bytes:
        recipe = self.recipes[name]
        return b"".join(self.chunks.get(fp) for fp in recipe.fps)

    def restore_into(self, name: str, out: np.ndarray) -> None:
        """Zero-extra-copy restore into a preallocated uint8 buffer."""
        recipe = self.recipes[name]
        off = 0
        for fp in recipe.fps:
            c = self.chunks.get(fp)
            out[off:off + len(c)] = np.frombuffer(c, dtype=np.uint8)
            off += len(c)

    # -- accounting ----------------------------------------------------------

    def dedup_ratio(self) -> float:
        """raw ingested bytes / stored bytes (higher = better; Fig. 6/7)."""
        stored = self.chunks.stored_bytes()
        return self.ingested_bytes / stored if stored else 1.0

    def missing(self, fps: Iterable[bytes]) -> List[bytes]:
        return [fp for fp in fps if not self.chunks.has(fp)]
