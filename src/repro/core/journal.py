"""Append-only, checksummed journal — the registry's crash-safe state log.

Record framing reuses the delivery wire format's checksummed records
(:func:`repro.delivery.wire.encode_record`): ``magic | version | type |
uvarint(len) | payload | blake2b-8``.  A reader stops at the first record
that fails to decode — a torn tail from a crash mid-append — and
:class:`Journal` truncates the file back to the last complete record before
appending again, so one crash never poisons subsequent recoveries.

Durability contract: with ``sync=True`` (the default) :meth:`Journal.append`
returns only after ``fsync``, so a registry commit acknowledged to the client
survives a crash of the registry process *and* of the host.

Snapshots (:func:`write_snapshot`) are just compacted record files written
via temp-file + ``fsync`` + atomic rename: recovery replays snapshot then
journal, and because the registry's record application is idempotent, a crash
between snapshot rename and journal truncation only causes harmless
re-application.

Replication: :class:`ReplicationLog` is the in-memory, offset-addressed tap
a primary registry feeds with every committed record (in commit order — the
same order the journal sees them).  Standby registries follow it over the
socket protocol's ``JOURNAL_SHIP``/``REPL_ACK`` ops (see
:mod:`repro.delivery.net`), resuming from the count of records they have
already applied; because the log stores the *encoded* checksummed record
bytes, a shipped record is re-verified end to end before a standby replays
it.  The log is logical — journal compaction does not disturb its offsets;
only a GC sweep that drops versions rolls it over to a new ``epoch``
(standbys at an older epoch must full-resync from an empty directory).

Concurrency contract
    ``Journal`` is **single-writer**: exactly one thread (the registry
    commit path, which the delivery frontends already serialize behind
    ``RegistryServer._registry_lock``) may call :meth:`Journal.append` /
    :meth:`Journal.reset`.  ``scan_records`` / recovery run before any
    writer exists.  :class:`ReplicationLog` by contrast is **thread-safe**
    (internal lock): one committer appends while any number of
    ``JOURNAL_SHIP`` handler threads read ``records_from`` concurrently.

Crash-recovery contract
    A record is *committed* iff it decodes cleanly (checksum included) from
    the snapshot-then-journal sequence.  After any crash, reopening a
    ``Journal`` truncates the torn tail, so the journal is always left in a
    state where every byte on disk belongs to a committed record; appends
    with ``sync=True`` make the record durable before returning.  The
    ``ReplicationLog`` is rebuilt on recovery from exactly those committed
    records, so a standby's resume offset (records applied) stays valid
    across primary *and* standby restarts.

Layering note: like ``core.pushpull``, this module's wire-format use is the
deliberate upward reference from core to the delivery layer; it is imported
lazily (call time) so ``import repro.core`` never recurses into
``repro.delivery``'s package init.
"""

from __future__ import annotations

import os
import threading
import time
from typing import Iterable, List, Optional, Tuple

from repro.obs import MetricsRegistry, NULL_REGISTRY

from .errors import JournalError

__all__ = ["Journal", "JournalError", "ReplicationLog", "scan_records",
           "write_snapshot", "write_snapshot_raw"]


def _wire():
    from repro.delivery import wire   # lazy: see layering note above
    return wire


def scan_records(path: str) -> Tuple[List[Tuple[int, bytes]], int, int]:
    """Read every complete record of ``path``.

    Returns ``(records, good_end, file_size)`` where ``records`` is a list of
    ``(type, payload)`` and ``good_end`` is the byte offset after the last
    record that decoded cleanly — everything past it is a torn tail.
    A missing file is an empty journal, not an error.
    """
    if not os.path.exists(path):
        return [], 0, 0
    with open(path, "rb") as f:
        buf = f.read()
    wire = _wire()
    records: List[Tuple[int, bytes]] = []
    off = 0
    while off < len(buf):
        try:
            rtype, payload, noff = wire.decode_record(buf, off)
        except wire.WireError:
            break                       # torn/corrupt tail: stop here
        records.append((rtype, payload))
        off = noff
    return records, off, len(buf)


class Journal:
    """Writable journal over one file: recover, replay, append, reset.

    ``metrics`` (a :class:`repro.obs.MetricsRegistry`) receives the
    ``journal_*`` series — append latency (fsync cost included) and the
    on-disk size gauge.  The owning registry passes its own; a bare journal
    defaults to the no-op registry, so metering never changes behavior.
    """

    def __init__(self, path: str, sync: bool = True,
                 metrics: MetricsRegistry = NULL_REGISTRY):
        self.path = path
        self.sync_writes = sync
        records, good_end, size = scan_records(path)
        self.torn_bytes_discarded = size - good_end
        if self.torn_bytes_discarded:
            with open(path, "r+b") as f:
                f.truncate(good_end)
        self._pending: List[Tuple[int, bytes]] = records  # guarded-by: external(single-writer: registry commit path behind RegistryServer._registry_lock)
        self._f = open(path, "ab")  # guarded-by: external(single-writer: registry commit path)
        self._size = good_end  # guarded-by: external(single-writer: registry commit path)
        self._m_append = metrics.histogram(
            "journal_append_seconds",
            "journal record append latency (fsync included)").labels()
        self._m_size = metrics.gauge(
            "journal_size_bytes", "journal file size on disk").labels()
        self._m_size.set(self._size)

    # ------------------------------------------------------------------ read

    def replay(self) -> List[Tuple[int, bytes]]:
        """The records recovered at open time (consumed on first call)."""
        records, self._pending = self._pending, []
        return records

    # ----------------------------------------------------------------- write

    def append(self, rtype: int, payload: bytes) -> None:
        self.append_raw(_wire().encode_record(rtype, payload))

    def append_raw(self, raw_record: bytes) -> None:
        """Append an already-encoded checksummed record — the commit path
        encodes each record once and hands the same bytes to the journal
        and the replication log, so shipped bytes are byte-identical to
        journaled ones."""
        if self._f is None:
            raise JournalError(f"journal {self.path} is closed")
        t0 = time.perf_counter()
        self._f.write(raw_record)
        self._f.flush()
        if self.sync_writes:
            os.fsync(self._f.fileno())
        self._m_append.observe(time.perf_counter() - t0)
        self._size += len(raw_record)
        self._m_size.set(self._size)

    def reset(self) -> None:
        """Truncate to empty — call only after the state the journal covers
        has been snapshotted durably elsewhere."""
        if self._f is None:
            raise JournalError(f"journal {self.path} is closed")
        self._f.close()
        self._f = open(self.path, "wb")
        self._f.flush()
        os.fsync(self._f.fileno())
        self._size = 0
        self._m_size.set(0)

    # ------------------------------------------------------------ accounting

    def size_bytes(self) -> int:
        return os.path.getsize(self.path) if os.path.exists(self.path) else 0

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class ReplicationLog:
    """Offset-addressed stream of committed records — the replication tap.

    Every committed registry record (push commit, metadata write) is
    appended here as its **encoded checksummed bytes**
    (:func:`repro.delivery.wire.encode_record`), so shipping a record to a
    standby is a copy of bytes whose integrity the standby re-verifies
    before replay.  Offsets are dense record ordinals: a standby that has
    applied ``k`` records resumes from offset ``k``.  Once every tracked
    replica has acked past an offset the primary trims the prefix below it
    (:meth:`trim_to`) — offsets stay absolute, so a follower behind the
    trimmed ``base`` is told to bootstrap from a snapshot instead of
    replaying history that no longer exists.

    ``epoch`` starts at 0 and increments only on :meth:`rollover` (a GC
    sweep that dropped versions — offsets from the old epoch are
    meaningless afterwards and followers at the old epoch are refused).

    Thread-safe: one committer appends while ship handlers read.
    """

    def __init__(self):
        self._epoch = 0  # guarded-by: _lock
        self._base = 0                     # guarded-by: _lock
        self._records: List[bytes] = []    # guarded-by: _lock
        self._lock = threading.Lock()

    @property
    def epoch(self) -> int:
        """Current epoch.  Read under the lock: ship handlers read it from
        server threads while recovery/apply paths bump it via
        :meth:`set_epoch` and GC via :meth:`rollover`."""
        with self._lock:
            return self._epoch

    def set_epoch(self, epoch: int) -> None:
        """Adopt a shipped/recovered epoch (standby catching up, or replay
        of an epoch record).  Writes must go through here, not attribute
        assignment — the guarded-by lint enforces it."""
        with self._lock:
            self._epoch = epoch

    def append(self, rtype: int, payload: bytes) -> int:
        """Record one committed ``(rtype, payload)``; returns its offset."""
        return self.append_raw(_wire().encode_record(rtype, payload))

    def append_raw(self, raw_record: bytes) -> int:
        """Record one already-encoded checksummed record (what the journal
        wrote / what a ship delivered) without re-encoding it."""
        with self._lock:
            self._records.append(raw_record)
            return self._base + len(self._records) - 1

    def head(self) -> int:
        """The next offset to be assigned == number of records ever logged
        this epoch."""
        with self._lock:
            return self._base + len(self._records)

    @property
    def base(self) -> int:
        """Lowest offset still held — everything below it was trimmed away
        once every tracked replica had acked past it."""
        with self._lock:
            return self._base

    def trim_to(self, offset: int) -> int:
        """Advance the log's base to ``offset``, dropping the record prefix
        below it.  Returns the number of records dropped.

        The primary calls this with ``min(replica_offsets)`` so in-epoch
        memory stays bounded by the slowest replica's lag; a standby's
        snapshot bootstrap calls it with the primary's head to adopt the
        shipped resume offset.  ``offset`` may exceed the current head (the
        bootstrap case: collapsed state has fewer records than the history
        it replaces) — the log is then empty with its next offset at
        ``offset``, so offsets are never re-issued.  Trimming at or below
        the current base is a no-op.
        """
        with self._lock:
            if offset <= self._base:
                return 0
            dropped = min(offset, self._base + len(self._records)) - self._base
            if dropped > 0:
                del self._records[:dropped]
            self._base = offset
            return dropped

    def records_from(self, start: int,
                     limit: Optional[int] = None) -> List[bytes]:
        """Encoded records from offset ``start`` (at most ``limit``).

        ``start == head()`` is a caught-up follower (empty list); beyond it
        — or behind a trimmed base — is a divergence and raises
        :class:`JournalError`.
        """
        with self._lock:
            if start < self._base:
                raise JournalError(
                    f"replication offset {start} is behind the log base "
                    f"{self._base} — full resync required")
            end = self._base + len(self._records)
            if start > end:
                raise JournalError(
                    f"replication offset {start} is ahead of the log head "
                    f"{end} — follower has diverged")
            out = self._records[start - self._base:]
            if limit is not None:
                out = out[:limit]
            return list(out)

    def dump(self) -> List[bytes]:
        """Every raw record this epoch, in order — what a snapshot persists
        so offsets survive a restart-after-compaction."""
        with self._lock:
            return list(self._records)

    def tail(self, n: int) -> List[bytes]:
        """The last ``n`` raw records (fewer if the log is shorter) — used
        by recovery to detect a journal that is a byte-identical suffix of
        the snapshot (crash between snapshot rename and journal truncate)."""
        with self._lock:
            return list(self._records[-n:]) if n > 0 else []

    def reset_to(self, epoch: int, base: int) -> None:
        """Adopt a snapshot-bootstrap position: ``epoch``, an empty log
        whose next offset is ``base`` — the in-memory equivalent of
        recovering a bootstrap snapshot (state records trimmed at the
        resume offset)."""
        with self._lock:
            self._epoch = epoch
            self._base = base
            self._records = []

    def rollover(self) -> int:
        """Start a new epoch with an empty log (after a version-dropping GC
        sweep; the caller re-seeds it from the retained state).  Returns the
        new epoch."""
        with self._lock:
            self._epoch += 1
            self._base = 0
            self._records = []
            return self._epoch


def write_snapshot(path: str, records: Iterable[Tuple[int, bytes]]) -> None:
    """Atomically write a compacted record file: temp + fsync + rename +
    directory fsync.  Readers either see the old snapshot or the complete
    new one, never a partial write."""
    wire = _wire()
    write_snapshot_raw(path, (wire.encode_record(rtype, payload)
                              for rtype, payload in records))


def write_snapshot_raw(path: str, raw_records: Iterable[bytes]) -> None:
    """:func:`write_snapshot` for already-encoded records (what a
    :class:`ReplicationLog` stores) — persisting the log's exact bytes with
    no decode/re-encode round-trip."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        for raw in raw_records:
            f.write(raw)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fsync_dir(os.path.dirname(os.path.abspath(path)) or ".")


def fsync_dir(dirname: str) -> None:
    """fsync a directory, making a completed rename inside it durable —
    an ``os.replace`` alone updates the directory entry only in memory;
    a crash before the directory inode reaches disk can undo the swap.
    Every atomic-rename site in the durable stores must call this (the
    durability lint enforces it)."""
    dfd = os.open(dirname, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)
