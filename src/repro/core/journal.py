"""Append-only, checksummed journal — the registry's crash-safe state log.

Record framing reuses the delivery wire format's checksummed records
(:func:`repro.delivery.wire.encode_record`): ``magic | version | type |
uvarint(len) | payload | blake2b-8``.  A reader stops at the first record
that fails to decode — a torn tail from a crash mid-append — and
:class:`Journal` truncates the file back to the last complete record before
appending again, so one crash never poisons subsequent recoveries.

Durability contract: with ``sync=True`` (the default) :meth:`Journal.append`
returns only after ``fsync``, so a registry commit acknowledged to the client
survives a crash of the registry process *and* of the host.

Snapshots (:func:`write_snapshot`) are just compacted record files written
via temp-file + ``fsync`` + atomic rename: recovery replays snapshot then
journal, and because the registry's record application is idempotent, a crash
between snapshot rename and journal truncation only causes harmless
re-application.

Layering note: like ``core.pushpull``, this module's wire-format use is the
deliberate upward reference from core to the delivery layer; it is imported
lazily (call time) so ``import repro.core`` never recurses into
``repro.delivery``'s package init.
"""

from __future__ import annotations

import os
from typing import Iterable, List, Tuple

from .errors import JournalError

__all__ = ["Journal", "JournalError", "scan_records", "write_snapshot"]


def _wire():
    from repro.delivery import wire   # lazy: see layering note above
    return wire


def scan_records(path: str) -> Tuple[List[Tuple[int, bytes]], int, int]:
    """Read every complete record of ``path``.

    Returns ``(records, good_end, file_size)`` where ``records`` is a list of
    ``(type, payload)`` and ``good_end`` is the byte offset after the last
    record that decoded cleanly — everything past it is a torn tail.
    A missing file is an empty journal, not an error.
    """
    if not os.path.exists(path):
        return [], 0, 0
    with open(path, "rb") as f:
        buf = f.read()
    wire = _wire()
    records: List[Tuple[int, bytes]] = []
    off = 0
    while off < len(buf):
        try:
            rtype, payload, noff = wire.decode_record(buf, off)
        except wire.WireError:
            break                       # torn/corrupt tail: stop here
        records.append((rtype, payload))
        off = noff
    return records, off, len(buf)


class Journal:
    """Writable journal over one file: recover, replay, append, reset."""

    def __init__(self, path: str, sync: bool = True):
        self.path = path
        self.sync_writes = sync
        records, good_end, size = scan_records(path)
        self.torn_bytes_discarded = size - good_end
        if self.torn_bytes_discarded:
            with open(path, "r+b") as f:
                f.truncate(good_end)
        self._pending: List[Tuple[int, bytes]] = records
        self._f = open(path, "ab")

    # ------------------------------------------------------------------ read

    def replay(self) -> List[Tuple[int, bytes]]:
        """The records recovered at open time (consumed on first call)."""
        records, self._pending = self._pending, []
        return records

    # ----------------------------------------------------------------- write

    def append(self, rtype: int, payload: bytes) -> None:
        if self._f is None:
            raise JournalError(f"journal {self.path} is closed")
        self._f.write(_wire().encode_record(rtype, payload))
        self._f.flush()
        if self.sync_writes:
            os.fsync(self._f.fileno())

    def reset(self) -> None:
        """Truncate to empty — call only after the state the journal covers
        has been snapshotted durably elsewhere."""
        if self._f is None:
            raise JournalError(f"journal {self.path} is closed")
        self._f.close()
        self._f = open(self.path, "wb")
        self._f.flush()
        os.fsync(self._f.fileno())

    # ------------------------------------------------------------ accounting

    def size_bytes(self) -> int:
        return os.path.getsize(self.path) if os.path.exists(self.path) else 0

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


def write_snapshot(path: str, records: Iterable[Tuple[int, bytes]]) -> None:
    """Atomically write a compacted record file: temp + fsync + rename +
    directory fsync.  Readers either see the old snapshot or the complete
    new one, never a partial write."""
    wire = _wire()
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        for rtype, payload in records:
            f.write(wire.encode_record(rtype, payload))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dirname = os.path.dirname(os.path.abspath(path)) or "."
    dfd = os.open(dirname, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)
