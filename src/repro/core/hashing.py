"""Fingerprints for chunks and CDMT nodes.

The paper uses Blake2b (RFC 7693) for chunk and internal-node hashes
(Sec. IV, VI-D).  We keep blake2b for all *identifiers* (dedup correctness
depends on it) and expose a truncated digest size — the paper notes the index
is ~KBs, and 16-byte digests keep it that way without meaningful collision
risk at registry scale (2^64 birthday bound).
"""

from __future__ import annotations

import hashlib
from typing import Iterable, List

DIGEST_SIZE = 16  # bytes


def chunk_fingerprint(data: bytes) -> bytes:
    """blake2b fingerprint of a data chunk (leaf node id)."""
    return hashlib.blake2b(data, digest_size=DIGEST_SIZE).digest()


def node_fingerprint(child_hashes: Iterable[bytes]) -> bytes:
    """blake2b over the concatenation of child hashes (internal node id)."""
    h = hashlib.blake2b(digest_size=DIGEST_SIZE)
    for c in child_hashes:
        h.update(c)
    return h.digest()


def checksum(data: bytes, size: int = 8) -> bytes:
    """Short blake2b integrity checksum (journal/wire records).  Not an
    identifier — dedup never keys on it — so a shorter digest is fine: it
    only needs to catch torn writes and bit rot."""
    return hashlib.blake2b(data, digest_size=size).digest()


def fingerprint_many(chunks: Iterable[bytes]) -> List[bytes]:
    return [chunk_fingerprint(c) for c in chunks]


def hex_short(fp: bytes, n: int = 8) -> str:
    return fp.hex()[:n]
