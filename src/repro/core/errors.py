"""Shared error types for the storage/delivery stack.

These live in ``repro.core`` (not ``repro.delivery``) so store/registry code
can raise them without an upward import; ``repro.delivery`` re-exports
:class:`DeliveryError` unchanged, so existing ``from repro.delivery import
DeliveryError`` call sites keep working.
"""

from __future__ import annotations


class DeliveryError(RuntimeError):
    """The delivery protocol could not complete — a required chunk is
    missing or unserved, a payload failed fingerprint verification, or a
    request named an unknown lineage/tag/fingerprint.  Always raised
    *before* any partial artifact is committed to a store."""


class JournalError(RuntimeError):
    """The registry journal (or snapshot) is unusable: a record decoded
    cleanly (checksum passed) but is inconsistent with the recorded state —
    e.g. a replayed commit reproduces a different CDMT root than the one the
    journal vouched for.  Torn tails are NOT this error; they are expected
    crash debris and are silently truncated on recovery."""
