"""Named fault points — crash-injection hooks for durability testing.

Production code marks each crash window of a multi-step durable operation
with ``faults.fire("name")``.  In normal operation every call is a no-op
costing one truthiness check of an empty dict; a test arms a hook
(:func:`arm`, or the richer harness in ``tests/faultpoints.py``) that
raises at exactly that point, simulating a process killed mid-operation.
Recovery is then exercised by reopening the registry from its directory —
the same path a real crash takes.

The point names form a stable catalog (see ``tests/faultpoints.py``): a
renamed or removed call site fails the fault-matrix tests, so the crash
windows the tests cover cannot silently drift from the ones the code has.

Layering: L0 leaf — imported by ``core.registry`` and ``delivery.net``;
imports nothing from the package.
"""

from __future__ import annotations

from typing import Callable, Dict, List

__all__ = ["arm", "armed", "disarm", "disarm_all", "fire"]

# Armed hooks by point name.  Module-level and unlocked on purpose: tests
# arm/disarm around single-threaded crash scenarios, and the empty-dict
# fast path keeps production cost to one truthiness check.
_hooks: Dict[str, Callable[[], None]] = {}


def fire(point: str) -> None:
    """Trigger the fault point ``point`` — a no-op unless a test armed it."""
    if not _hooks:
        return
    hook = _hooks.get(point)
    if hook is not None:
        hook()


def arm(point: str, hook: Callable[[], None]) -> None:
    """Install ``hook`` to run whenever ``point`` fires (usually: raise)."""
    _hooks[point] = hook


def disarm(point: str) -> None:
    """Remove the hook for ``point`` (missing is fine)."""
    _hooks.pop(point, None)


def disarm_all() -> None:
    """Remove every armed hook — restores the zero-cost fast path."""
    _hooks.clear()


def armed() -> List[str]:
    """The currently armed point names, sorted."""
    return sorted(_hooks)
