"""Serving launcher: pull weights via CDMT, serve batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --requests 16 --new-tokens 8
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import get_config, list_archs
from repro.models.api import Model
from repro.serving import Request, ServeConfig, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    model = Model(get_config(args.arch, reduced=args.reduced))
    params = model.init_params(jax.random.PRNGKey(args.seed))
    engine = ServingEngine(model, params,
                           ServeConfig(batch_size=args.batch,
                                       max_len=args.prompt_len + args.new_tokens
                                       + model.cfg.decode_margin))
    rng = np.random.default_rng(args.seed)
    reqs = [Request(id=i,
                    prompt=rng.integers(0, model.cfg.vocab,
                                        size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    metrics = engine.serve(reqs)
    print(f"served {metrics['requests']} requests in {metrics['wall_s']:.2f}s "
          f"→ {metrics['tokens_per_s']:.1f} new tokens/s")
    print("sample output:", reqs[0].output[:8])


if __name__ == "__main__":
    main()
