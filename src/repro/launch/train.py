"""Training launcher.

Reduced-config CPU run (examples/CI):
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 200 --batch 8 --seq 128

Production (per-host process on a real cluster; here the mesh falls back to
the local device set):
    python -m repro.launch.train --arch qwen2-72b --steps 10000 ...

The launcher wires together: config → model → data pipeline → fault-tolerant
Trainer (CDMT-dedup checkpoints to a registry directory) and resumes
automatically from the latest checkpoint on restart.
"""

from __future__ import annotations

import argparse
import time

from repro.checkpoint import CheckpointConfig
from repro.configs.base import get_config, list_archs
from repro.core.registry import Registry
from repro.data import DataConfig
from repro.models.api import Model
from repro.optim import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.train_step import TrainConfig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default=None,
                    help="registry directory (persistent across restarts)")
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    model = Model(get_config(args.arch, reduced=args.reduced))
    print(f"arch={args.arch} reduced={args.reduced} "
          f"params={model.param_count():,}")

    data = DataConfig(vocab=model.cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, n_hosts=1, seed=args.seed)
    cfg = TrainerConfig(
        total_steps=args.steps,
        ckpt=CheckpointConfig(lineage=f"{args.arch}",
                              every_steps=args.ckpt_every,
                              async_push=args.async_ckpt),
        train=TrainConfig(n_micro=args.n_micro,
                          adamw=AdamWConfig(lr=args.lr),
                          warmup_steps=max(1, args.steps // 20),
                          total_steps=args.steps),
    )
    registry = Registry(directory=args.ckpt_dir)
    trainer = Trainer(model, data, cfg, registry=registry)

    t0 = time.time()

    def log(step, m):
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {m['loss']:.4f}  "
                  f"gnorm {m['grad_norm']:.3f}  lr {m['lr']:.2e}  "
                  f"{m['step_s']*1e3:.0f} ms/step")

    state = trainer.run(on_step=log)
    wall = time.time() - t0
    s = trainer.ckpt.wire_summary()
    print(f"done: {args.steps} steps in {wall:.1f}s")
    print(f"checkpoints: {s['checkpoints']}  wire {s['wire_bytes']/2**20:.1f} "
          f"MiB vs raw {s['raw_bytes']/2**20:.1f} MiB "
          f"(savings {s['savings']:.1%})")
    return state


if __name__ == "__main__":
    main()
