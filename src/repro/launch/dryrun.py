import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- multi-pod dry-run entrypoint -------------------------------------------
# The two lines above MUST run before any jax import: jax locks the device
# count on first backend init.  512 host devices stand in for 2 TPU v5e pods.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b \
#       --shape train_4k --mesh single
#   PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
#
# Per cell: lower + compile against the production mesh, print
# memory_analysis() (fits-in-HBM proof) and cost_analysis(), run the
# trip-count-aware HLO cost walker (launch/hlo_cost.py), and append a JSON
# record under benchmarks/results/dryrun/.
# -----------------------------------------------------------------------------

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs.base import SHAPES, get_config, list_archs
from repro.launch import hlo_cost, mesh as mesh_lib
from repro.launch.cells import build_cell, lower_cell

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

# TPU v5e per-chip peaks (mesh.py)
PEAK = {"flops": mesh_lib.PEAK_FLOPS_BF16, "hbm": mesh_lib.HBM_BW,
        "ici": mesh_lib.ICI_BW_PER_LINK}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             rules: Optional[Dict[str, Any]] = None,
             n_micro: Optional[int] = None,
             tag: str = "baseline",
             cfg_overrides: Optional[Dict[str, Any]] = None,
             verbose: bool = True) -> Dict[str, Any]:
    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "chips": n_chips, "tag": tag,
                           "status": "ok",
                           "cfg_overrides": {k: str(v) for k, v in
                                             (cfg_overrides or {}).items()}}
    try:
        t0 = time.time()
        cell = build_cell(arch, shape_name, mesh, rules=rules, n_micro=n_micro,
                          cfg_overrides=cfg_overrides)
        lowered = lower_cell(cell)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes": int(ma.argument_size_in_bytes
                              + ma.output_size_in_bytes
                              + ma.temp_size_in_bytes
                              - ma.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):      # older jax: one dict per device
            ca = ca[0] if ca else {}
        rec["xla_cost"] = {k: float(v) for k, v in ca.items()
                           if k in ("flops", "bytes accessed", "transcendentals")}

        t2 = time.time()
        hlo = compiled.as_text()
        cost = hlo_cost.HloCostModel(hlo).entry_cost()
        rec["walk_s"] = round(time.time() - t2, 2)
        rec["hlo_cost"] = cost.as_dict()

        meta = cell.meta
        rec["meta"] = meta
        # --- roofline terms (seconds per step, per chip) ---------------------
        compute_s = cost.flops / PEAK["flops"]
        memory_s = cost.hbm_bytes / PEAK["hbm"]
        # ICI: per-chip wire bytes / per-chip link bandwidth.  A 2-D torus
        # axis has ~3 usable links per direction pair; use 3 links aggregate.
        coll_s = cost.collective_bytes / (3 * PEAK["ici"])
        model_flops_step = (meta["flops_factor"] * meta["active_params"]
                            * meta["tokens_per_step"])
        model_flops_chip = model_flops_step / n_chips
        rec["roofline"] = {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": coll_s,
            "dominant": max(
                (("compute", compute_s), ("memory", memory_s),
                 ("collective", coll_s)), key=lambda kv: kv[1])[0],
            "model_flops_per_chip": model_flops_chip,
            "useful_flops_ratio": (model_flops_chip / cost.flops
                                   if cost.flops else 0.0),
            "step_time_bound_s": max(compute_s, memory_s, coll_s),
            "mfu_bound": model_flops_chip / PEAK["flops"]
                         / max(compute_s, memory_s, coll_s)
                         if max(compute_s, memory_s, coll_s) > 0 else 0.0,
        }
        if verbose:
            m = rec["memory"]
            r = rec["roofline"]
            print(f"[{arch} × {shape_name} × {mesh_kind}] OK  "
                  f"compile={rec['compile_s']}s  "
                  f"mem/chip={m['peak_bytes']/2**30:.2f}GiB  "
                  f"compute={r['compute_s']*1e3:.1f}ms "
                  f"memory={r['memory_s']*1e3:.1f}ms "
                  f"coll={r['collective_s']*1e3:.1f}ms "
                  f"dominant={r['dominant']} mfu_bound={r['mfu_bound']:.2%}")
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_kind}] FAIL  {rec['error']}")
    return rec


def save_record(rec: Dict[str, Any], out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
    if rec.get("tag", "baseline") != "baseline":
        name += f"__{rec['tag']}"
    path = os.path.join(out_dir, name + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def applicable_cells():
    for arch in list_archs():
        cfg = get_config(arch)
        for shape_name in cfg.applicable_shapes():
            yield arch, shape_name


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="every applicable (arch × shape)")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--rules", default=None,
                    help='JSON rule overrides, e.g. \'{"seq_sp": null}\'')
    ap.add_argument("--cfg", default=None,
                    help='JSON ModelConfig overrides, e.g. '
                         '\'{"wkv_impl": "chunked"}\'')
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--out-dir", default=os.path.normpath(RESULTS_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    rules = json.loads(args.rules) if args.rules else None
    cfg_overrides = json.loads(args.cfg) if args.cfg else None
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = list(applicable_cells())
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for arch, shape_name in cells:
        for mk in meshes:
            name = f"{arch}__{shape_name}__{mk}"
            if args.tag != "baseline":
                name += f"__{args.tag}"
            path = os.path.join(args.out_dir, name + ".json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("status") == "ok":
                        print(f"[{arch} × {shape_name} × {mk}] cached OK")
                        continue
            rec = run_cell(arch, shape_name, mk, rules=rules,
                           n_micro=args.n_micro, tag=args.tag,
                           cfg_overrides=cfg_overrides)
            save_record(rec, args.out_dir)
            n_fail += rec["status"] != "ok"
    print(f"done: {len(cells) * len(meshes)} cells, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
