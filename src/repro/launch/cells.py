"""Cell construction: (arch × shape × mesh) → lowerable step + shardings.

A *cell* is one entry of the dry-run/roofline matrix.  ``build_cell``
returns everything needed to ``jit(...).lower(...)``:

  fn            step function (train_step / prefill / decode_step)
  args          ShapeDtypeStruct pytree of inputs (no allocation)
  in_shardings  NamedSharding pytree matching args
  donate        argnums to donate (state / cache)
  meta          tokens-per-step, model params, family, n_micro, ...

Baseline sharding rules come from ``parallel.sharding.DEFAULT_RULES`` plus
per-cell overrides below; perf iterations (EXPERIMENTS.md §Perf) swap these
via the ``rules`` argument.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, get_config
from repro.models import serve
from repro.models.api import Model
from repro.parallel import sharding as sh
from repro.runtime.train_step import (TrainConfig, abstract_train_state,
                                      make_train_step)

DEFAULT_N_MICRO = 4


def baseline_rule_overrides(cfg: ModelConfig, shape: ShapeSpec,
                            mesh: Mesh) -> Dict[str, Any]:
    """Per-cell sharding-rule overrides (the baseline; §Perf hillclimbs these).

    Divisibility-aware: any logical axis whose size does not divide over the
    mesh axis it maps to is replicated instead (with a better-sharded
    substitute where one exists) — e.g. rwkv6's 40 heads and GQA kv<16 heads
    cannot shard over model=16, so the cache shards its sequence axis.
    """
    msize = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = msize.get("model", 1)
    rules: Dict[str, Any] = {}

    if cfg.n_kv_heads % model_n != 0:
        # kv projections + kv activations are small; replicate over model
        rules["kv_heads"] = None
        rules["act_kv"] = None
    if cfg.family == "rwkv" and cfg.n_heads % model_n != 0:
        rules["act_heads"] = None        # (B,S,40,64) cannot shard heads

    if shape.kind in ("decode", "prefill"):
        # (prefill RETURNS the cache: its sharding bounds output bytes)
        if cfg.use_mla:
            # MLA latent cache has no heads axis: shard time over model
            rules["cache_seq"] = "model"
        if cfg.n_kv_heads % model_n != 0:
            # MQA/GQA<model: cache heads cannot shard; shard cache time
            rules["cache_heads"] = None
            rules["cache_seq"] = "model"
        if cfg.family == "rwkv" and cfg.n_heads % model_n != 0:
            rules["cache_heads"] = None  # wkv state (B,40,64,64)
        if shape.name == "long_500k":
            # batch=1: batch axes cannot shard; give 'data' to the cache
            # sequence (zamba2 attn KV) — rwkv state has no seq axis and
            # stays replicated per the rules above.
            rules["batch"] = None
            rules["cache_batch"] = None
            rules["cache_seq"] = "data"
    return rules


def _batch_pspec(shape_name: str, mesh: Mesh) -> Any:
    if shape_name == "long_500k":
        return None
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes or None


def _spec_tree_shardings(mesh: Mesh, tree):
    """ParamSpec tree → NamedSharding tree under the ambient rules."""
    from repro.models import spec as S
    return S.map_axes(tree, lambda s: NamedSharding(
        mesh, sh.logical_to_pspec(s.axes)))


def _axes_to_sharding(mesh: Mesh, axes_tree, struct_tree):
    """Logical-axis tuples tree → NamedSharding tree (matching structs)."""
    return jax.tree.map(
        lambda axes, _: NamedSharding(mesh, sh.logical_to_pspec(tuple(axes))),
        axes_tree, struct_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    mesh: Mesh
    fn: Any
    args: Tuple
    in_shardings: Tuple
    donate: Tuple[int, ...]
    meta: Dict[str, Any]
    rules: Dict[str, Any]
    out_shardings: Any = None


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               rules: Optional[Dict[str, Any]] = None,
               n_micro: Optional[int] = None,
               remat: Optional[bool] = None,
               cfg_overrides: Optional[Dict[str, Any]] = None) -> Cell:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    model = Model(cfg)
    eff_rules = baseline_rule_overrides(cfg, shape, mesh)
    if rules:
        eff_rules.update(rules)

    n_params = model.param_count()
    n_active = model.active_param_count()
    meta: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "family": cfg.family,
        "params": n_params, "active_params": n_active,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "rules": {k: str(v) for k, v in eff_rules.items()},
    }

    with sh.use_mesh(mesh, eff_rules):
        if shape.kind == "train":
            nm = n_micro or cfg.train_n_micro or DEFAULT_N_MICRO
            from repro.optim import AdamWConfig
            tc = TrainConfig(
                n_micro=nm,
                # honor the arch's optimizer-state dtype (bf16 for 70B+)
                adamw=AdamWConfig(state_dtype=cfg.opt_state_dtype),
                accum_dtype=cfg.grad_accum_dtype)
            step = make_train_step(model, tc)
            state = abstract_train_state(model, tc)
            b, s = shape.global_batch, shape.seq_len
            mb = b // nm
            batch = model.input_specs(shape)
            # (B, ...) -> (n_micro, B/n_micro, ...)
            batch = {k: jax.ShapeDtypeStruct((nm, mb) + v.shape[1:], v.dtype)
                     for k, v in batch.items()}
            bd = _batch_pspec(shape_name, mesh)
            batch_sh = {k: NamedSharding(
                mesh, P(*((None, bd) + (None,) * (len(v.shape) - 2))))
                for k, v in batch.items()}
            pspecs = _spec_tree_shardings(mesh, model.specs)
            opt_sh = {"m": pspecs, "v": pspecs,
                      "count": NamedSharding(mesh, P())}
            state_sh = type(state)(params=pspecs, opt=opt_sh,
                                   step=NamedSharding(mesh, P()))
            meta.update(tokens_per_step=b * s, step_kind="train",
                        n_micro=nm, flops_factor=6)
            return Cell(arch, shape, mesh, step, (state, batch),
                        (state_sh, batch_sh), (0,), meta, eff_rules)

        if shape.kind == "prefill":
            def prefill_fn(params, batch):
                return model.prefill(params, batch)

            params = model.abstract_params()
            batch = model.input_specs(shape)
            bd = _batch_pspec(shape_name, mesh)
            batch_sh = {k: NamedSharding(
                mesh, P(*((bd,) + (None,) * (len(v.shape) - 1))))
                for k, v in batch.items()}
            pspecs = _spec_tree_shardings(mesh, model.specs)
            # pin the RETURNED cache's sharding (it dominates output bytes;
            # XLA otherwise materializes under-sharded KV for GQA<model)
            s = shape.seq_len + (cfg.n_patches if cfg.family == "vlm" else 0)
            cache_struct = serve.cache_struct(cfg, shape.global_batch,
                                              s + cfg.decode_margin)
            cache_sh = _axes_to_sharding(mesh, serve.cache_axes(cfg),
                                         cache_struct)
            logits_sh = NamedSharding(mesh, P(bd, None, None))
            meta.update(tokens_per_step=shape.global_batch * shape.seq_len,
                        step_kind="prefill", flops_factor=2)
            cell = Cell(arch, shape, mesh, prefill_fn, (params, batch),
                        (pspecs, batch_sh), (), meta, eff_rules)
            cell.meta["out_shardings"] = True
            cell.out_shardings = (cache_sh, logits_sh)
            return cell

        # decode
        def decode_fn(params, cache, tokens):
            return model.decode_step(params, cache, tokens)

        params = model.abstract_params()
        specs = model.input_specs(shape)
        cache, tokens = specs["cache"], specs["tokens"]
        bd = _batch_pspec(shape_name, mesh)
        tok_sh = NamedSharding(mesh, P(bd, None))
        cache_sh = _axes_to_sharding(mesh, serve.cache_axes(cfg), cache)
        pspecs = _spec_tree_shardings(mesh, model.specs)
        meta.update(tokens_per_step=shape.global_batch, step_kind="decode",
                    flops_factor=2)
        return Cell(arch, shape, mesh, decode_fn, (params, cache, tokens),
                    (pspecs, cache_sh, tok_sh), (1,), meta, eff_rules)


def lower_cell(cell: Cell):
    """jit + lower under the cell's mesh/rules (tracing reads the context)."""
    with sh.use_mesh(cell.mesh, cell.rules):
        kw = {}
        if cell.out_shardings is not None:
            kw["out_shardings"] = cell.out_shardings
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate, **kw)
        return jitted.lower(*cell.args)
