"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.

Single pod : (data=16, model=16)              = 256 chips (TPU v5e pod)
Multi-pod  : (pod=2, data=16, model=16)       = 512 chips

``pod`` is declared outermost so XLA maps it onto the slowest (inter-pod)
links; by default it extends data parallelism (gradient all-reduce across
pods amortized over grad accumulation), and the pipeline launcher reuses it
as the pipeline-stage axis.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

# TPU v5e hardware constants (per chip) — used by benchmarks/roofline.py
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s per link (~3 links usable per axis)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests / hillclimb sweeps).  Auto axis types: the
    framework shards via PartitionSpecs + logical-axis constraints.
    ``AxisType`` only exists on newer jax; Auto is the default there anyway,
    so older versions just omit the kwarg."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def require_devices(n: int) -> None:
    have = len(jax.devices())
    if have < n:
        raise RuntimeError(
            f"mesh needs {n} devices but only {have} present — the dry-run "
            f"entrypoint must set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n} BEFORE any jax import (see launch/dryrun.py)")
