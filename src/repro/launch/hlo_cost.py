"""Trip-count-aware cost model over compiled (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts each ``while`` body
ONCE — a model expressed as ``lax.scan`` over 80 layers reports 1/80th of
its real FLOPs.  Every model here scans (layers, microbatches, attention
blocks, SSM segments), so the roofline would be garbage without loop-aware
accounting.  XLA:CPU/TPU attach ``backend_config={"known_trip_count":...}``
to counted loops, which lets us do the multiplication exactly.

The walker parses the optimized HLO module and computes, per device:

* ``flops``            — 2·M·N·K for dots (+1 flop/elem for fused math),
* ``hbm_bytes``        — operand+result bytes of top-level instructions
                         (fusion interiors are register/cache traffic, not
                         HBM — matching how XLA's own model counts),
* ``collective_bytes`` — wire bytes per device with op-specific ring
                         factors: all-gather/reduce-scatter move
                         size·(g-1)/g, all-reduce 2·size·(g-1)/g,
                         all-to-all size·(g-1)/g, collective-permute size,
* per-collective-op breakdown (for the §Perf iteration log).

All quantities are already *per partition* because the module is post-SPMD.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_ITEMSIZE = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f4e2m1fn": 1, "f8e8m0fnu": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples by summing elements)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _ITEMSIZE:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _ITEMSIZE[dtype]
    return total


def shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    operands: List[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\)|[\w\[\],{}\d]+?))\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_COND_BODY = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if "/*" in line:
            line = re.sub(r"/\*.*?\*/", "", line)
        if cur is None:
            m = _COMP_HEAD.match(line.strip())
            if m:
                cur = Computation(name=m.group(2), instrs=[])
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            name, rtype, opcode, rest = m.groups()
            # operands: %refs inside the first paren group (up to matching
            # close is overkill; refs after attrs like calls= are filtered
            # by the specific handlers that need them)
            head = rest.split("), ")[0]
            ops = _OPERAND.findall(head)
            cur.instrs.append(Instr(name=name, result_type=rtype.strip(),
                                    opcode=opcode, operands=ops, raw=line))
    return comps, entry


COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    coll_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + int(v * mult)

    def as_dict(self) -> Dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "collective_bytes": self.collective_bytes,
                "coll_by_op": self.coll_by_op, "coll_count": self.coll_count}


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_module(hlo_text)
        self._memo: Dict[Tuple[str, bool], Cost] = {}
        # instruction result types per computation (operand shape lookup)
        self._types: Dict[str, Dict[str, str]] = {
            cname: {i.name: i.result_type for i in c.instrs}
            for cname, c in self.comps.items()
        }

    # -- per-instruction ------------------------------------------------------

    def _group_size(self, raw: str, opcode: str) -> int:
        m = _GROUPS_IOTA.search(raw)
        if m:
            # replica_groups=[G,S] — G groups of size S
            return max(1, int(m.group(2)))
        m = _GROUPS_LIST.search(raw)
        if m:
            return max(1, len(m.group(1).split(",")))
        return 1

    def _collective_bytes(self, ins: Instr, comp: str) -> Tuple[str, float]:
        g = self._group_size(ins.raw, ins.opcode)
        ring = (g - 1) / g if g > 1 else 0.0
        op = next(c for c in COLLECTIVES if ins.opcode.startswith(c))
        if op == "all-gather":
            size = shape_bytes(ins.result_type)      # gathered output
            return op, size * ring
        if op == "reduce-scatter":
            size = sum(shape_bytes(self._operand_type(ins, comp, i))
                       for i in range(len(ins.operands)))
            return op, size * ring
        if op == "all-reduce":
            size = shape_bytes(ins.result_type)
            return op, 2.0 * size * ring
        if op == "all-to-all":
            size = shape_bytes(ins.result_type)
            return op, size * ring
        # collective-permute: moves its operand once
        size = shape_bytes(ins.result_type)
        return op, size

    def _operand_type(self, ins: Instr, comp: str, idx: int) -> str:
        if idx >= len(ins.operands):
            return ""
        return self._types.get(comp, {}).get(ins.operands[idx], "")

    def _dot_flops(self, ins: Instr, comp: str) -> float:
        out_elems = shape_elems(ins.result_type)
        m = _CONTRACT.search(ins.raw)
        k = 1
        lhs_t = self._operand_type(ins, comp, 0)
        if m and lhs_t:
            sm = _SHAPE_RE.search(lhs_t)
            if sm and sm.group(2):
                dims = [int(d) for d in sm.group(2).split(",")]
                for ci in m.group(1).split(","):
                    if ci != "" and int(ci) < len(dims):
                        k *= dims[int(ci)]
        return 2.0 * out_elems * k

    # -- walk -----------------------------------------------------------------

    def cost_of(self, comp_name: str, inside_fusion: bool = False) -> Cost:
        key = (comp_name, inside_fusion)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(comp_name)
        total = Cost()
        if comp is None:
            self._memo[key] = total
            return total
        for ins in comp.instrs:
            total.add(self._instr_cost(ins, comp_name, inside_fusion))
        self._memo[key] = total
        return total

    def _instr_cost(self, ins: Instr, comp: str, inside_fusion: bool) -> Cost:
        c = Cost()
        op = ins.opcode
        if op in ("parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "after-all", "partition-id", "replica-id",
                  "iota"):
            return c
        if any(op.startswith(x) for x in COLLECTIVES):
            kind, nbytes = self._collective_bytes(ins, comp)
            c.collective_bytes += nbytes
            c.coll_by_op[kind] = c.coll_by_op.get(kind, 0.0) + nbytes
            c.coll_count[kind] = c.coll_count.get(kind, 0) + 1
            if not inside_fusion:
                c.hbm_bytes += shape_bytes(ins.result_type)
            return c
        if op == "while":
            trip = 1
            m = _TRIP.search(ins.raw)
            if m:
                trip = int(m.group(1))
            m = _COND_BODY.search(ins.raw)
            if m:
                cond, body = m.groups()
                c.add(self.cost_of(body), trip)
                c.add(self.cost_of(cond), trip)
            return c
        if op == "conditional":
            m = _BRANCHES.search(ins.raw)
            if m:
                branches = _OPERAND.findall(m.group(1))
                costs = [self.cost_of(b) for b in branches]
                if costs:           # worst-case branch
                    worst = max(costs, key=lambda x: x.flops + x.hbm_bytes)
                    c.add(worst)
            return c
        if op in ("call", "custom-call", "map", "reduce", "reduce-window",
                  "sort", "scatter", "select-and-scatter"):
            m = _TO_APPLY.search(ins.raw)
            if m:
                c.add(self.cost_of(m.group(1), inside_fusion=True))
        if op == "fusion":
            m = _CALLS.search(ins.raw)
            called = m.group(1) if m else None
            if called:
                inner = self.cost_of(called, inside_fusion=True)
                c.flops += inner.flops
                c.collective_bytes += inner.collective_bytes
                for k, v in inner.coll_by_op.items():
                    c.coll_by_op[k] = c.coll_by_op.get(k, 0.0) + v
            # HBM traffic of a fusion: per-operand *utilization* (mirrors
            # XLA's cost analysis) — an operand consumed only through
            # dynamic-slice contributes slice-sized reads; the aliased
            # target of a root dynamic-update-slice contributes nothing
            # (in-place) and the write is update-sized.
            if not inside_fusion:
                res = shape_bytes(ins.result_type)
                util = self._fusion_param_utilization(called)
                read = 0
                for i in range(len(ins.operands)):
                    full = shape_bytes(self._operand_type(ins, comp, i))
                    u = util.get(i, -1) if util is not None else -1
                    read += full if u < 0 else min(u, full)
                write = res
                if util is not None and util.get("root_write", -1) >= 0:
                    write = min(res, util["root_write"])
                c.hbm_bytes += read + write
            return c

        # plain compute instruction
        if op == "dynamic-update-slice":
            # in-place: traffic = read+write of the update slice
            upd = shape_bytes(self._operand_type(ins, comp, 1))
            c.hbm_bytes += 2 * upd
            return c
        if op == "dynamic-slice":
            c.hbm_bytes += 2 * shape_bytes(ins.result_type)
            return c
        if op == "dot":
            c.flops += self._dot_flops(ins, comp)
        elif op == "convolution":
            # rough: 2 × out_elems × (kernel elems / out-channels)
            out = shape_elems(ins.result_type)
            kern = shape_elems(self._operand_type(ins, comp, 1))
            c.flops += 2.0 * out * max(1, kern // max(1, out and 1))
        else:
            c.flops += float(shape_elems(ins.result_type))   # 1 flop/elem
        if not inside_fusion:
            opnd = sum(shape_bytes(self._operand_type(ins, comp, i))
                       for i in range(len(ins.operands)))
            c.hbm_bytes += opnd + shape_bytes(ins.result_type)
        return c

    def _fusion_param_utilization(self, called: Optional[str]):
        """Per-parameter-index HBM read bytes for a fused computation, or -1
        (full read).  'root_write' maps to the write size when the root is a
        dynamic-update-slice (in-place update)."""
        if called is None or called not in self.comps:
            return None
        if not hasattr(self, "_util_memo"):
            self._util_memo: Dict[str, Dict] = {}
        if called in self._util_memo:
            return self._util_memo[called]
        comp = self.comps[called]
        pidx: Dict[str, int] = {}
        for ii in comp.instrs:
            if ii.opcode == "parameter":
                pm = re.search(r"parameter\((\d+)\)", ii.raw)
                if pm:
                    pidx[ii.name] = int(pm.group(1))
        util: Dict = {i: 0 for i in pidx.values()}   # start: unused = 0 read
        for ii in comp.instrs:
            for oi, op_name in enumerate(ii.operands):
                if op_name not in pidx:
                    continue
                i = pidx[op_name]
                if util.get(i, -1) < 0:
                    continue                          # already full
                if ii.opcode in ("dynamic-slice", "slice"):
                    util[i] = util[i] + shape_bytes(ii.result_type)
                elif ii.opcode == "dynamic-update-slice" and oi == 0:
                    pass                              # aliased target: free
                else:
                    util[i] = -1                      # full read
        root = comp.instrs[-1] if comp.instrs else None
        if root is not None and root.opcode == "dynamic-update-slice":
            upd = self._types.get(called, {}).get(
                root.operands[1] if len(root.operands) > 1 else "", "")
            util["root_write"] = shape_bytes(upd) if upd else -1
        else:
            util["root_write"] = -1
        self._util_memo[called] = util
        return util

    def entry_cost(self) -> Cost:
        entry = self.entry
        if entry is None:
            entry = next((n for n in self.comps if n.startswith("main")),
                         next(iter(self.comps)))
        return self.cost_of(entry)


def analyze_hlo(hlo_text: str) -> Dict:
    return HloCostModel(hlo_text).entry_cost().as_dict()
