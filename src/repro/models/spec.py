"""Parameter specification machinery.

Models declare parameters as trees of ``ParamSpec`` (shape + *logical* axis
names + init).  From one spec tree we derive:

  * ``abstract(tree)``   — ShapeDtypeStruct tree (dry-run lowering, no alloc)
  * ``initialize(tree)`` — materialized arrays (smoke tests / examples)
  * ``partition_specs``  — PartitionSpec tree via the active sharding rules

Logical axes (resolved by ``repro.parallel.sharding`` rules):
  embed, vocab, heads, kv_heads, head_dim, mlp, experts, layers, seq,
  batch, state, conv, lora, null
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed | small
    scale: float = 0.02
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def p(shape, axes, init="normal", scale=0.02, dtype=jnp.float32) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), init, scale, dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def abstract(tree):
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree,
        is_leaf=is_spec)


def initialize(tree, key: jax.Array):
    """Materialize parameters (reduced configs only)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, spec.dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, spec.dtype)
        elif spec.init in ("normal", "embed"):
            arr = (jax.random.normal(k, spec.shape, jnp.float32)
                   * spec.scale).astype(spec.dtype)
        elif spec.init == "small":
            arr = (jax.random.normal(k, spec.shape, jnp.float32)
                   * (spec.scale * 0.1)).astype(spec.dtype)
        else:
            raise ValueError(spec.init)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def param_count(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


def map_axes(tree, fn: Callable[[ParamSpec], Any]):
    return jax.tree.map(fn, tree, is_leaf=is_spec)
