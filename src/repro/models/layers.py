"""Model-layer primitives shared by all 10 architectures.

Pure-functional JAX: params are dict trees of arrays (f32 masters), compute
is bf16 (cast at use), normalization/softmax/state in f32.  Every layer
annotates activations with *logical* sharding axes via
``repro.parallel.sharding.constrain`` (no-op without a mesh).

HLO-size discipline: everything sequence-long is a ``lax.scan`` (blockwise
attention, SSM/RWKV recurrences, microbatch accumulation lives upstream), so
dry-run compiles stay small even for 80-layer models.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import constrain

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: Optional[jax.Array], eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    return y.astype(x.dtype)


def layer_norm(x: jax.Array, weight: Optional[jax.Array],
               bias: Optional[jax.Array], eps: float = 1e-5) -> jax.Array:
    """LayerNorm; with weight=bias=None this is OLMo's non-parametric LN."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(params: Params, name: str, x: jax.Array, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, params[name]["scale"])
    if kind == "layernorm":
        return layer_norm(x, params[name]["scale"], params[name]["bias"])
    if kind == "nonparam_ln":
        return layer_norm(x, None, None)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) rotate pairs (even, odd) by position angles."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                  # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv        # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention in pure jnp — O(S) memory, scan-based
# ---------------------------------------------------------------------------


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, block_q: int = 512,
                        block_kv: int = 512) -> jax.Array:
    """Memory-efficient attention.  q:(B,Sq,H,D) k,v:(B,Skv,H,D) (heads
    matched).  Scans q blocks (outer) and kv blocks (inner, running
    max/sum/acc in f32).  Assumes Sq == Skv when causal (training)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    dv = v.shape[-1]                  # MLA: value head dim ≠ qk head dim
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (sq, bq, skv, bkv)
    nq, nkv = sq // bq, skv // bkv
    scale = 1.0 / np.sqrt(d)

    # TPU-flash numerics: q/k/v/p move as compute dtype (bf16 — HALF the
    # HBM traffic of the dominant inner loop, §Perf), while scores, the
    # running max/sum and the output accumulator stay f32 (MXU accumulates
    # f32 from bf16 operands natively).
    io_dt = q.dtype
    qb = q.reshape(b, nq, bq, h, d)
    kb = k.reshape(b, nkv, bkv, h, d)
    vb = v.reshape(b, nkv, bkv, h, dv)

    @jax.checkpoint
    def q_step(_, qi_and_block):
        # Rematted: without this the *backward* of the scanned kv loop saves
        # the (nq, nkv, B, H, bq, bkv) f32 logits — the O(S²) memory flash
        # attention exists to avoid.  Rematting per q-block bounds saved
        # residuals to the q-block inputs (found via hlo_cost HBM breakdown).
        qi, qblk = qi_and_block                       # qblk: (B, bq, H, D)

        def kv_step(carry, ki_and_kv):
            m, l, acc = carry
            ki, kblk, vblk = ki_and_kv
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = qi * bq + jnp.arange(bq)[:, None]
                kpos = ki * bkv + jnp.arange(bkv)[None, :]
                s = jnp.where((qpos >= kpos)[None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(io_dt), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, bq), jnp.float32)
        a0 = jnp.zeros((b, h, bq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nkv), kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4)))
        out = (acc / l[..., None]).transpose(0, 2, 1, 3)         # (B,bq,H,D)
        return None, out

    _, outs = jax.lax.scan(q_step, None,
                           (jnp.arange(nq), qb.transpose(1, 0, 2, 3, 4)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


def naive_attention(q, k, v, *, causal: bool) -> jax.Array:
    """Full-logits attention — analysis mode (exact FLOPs visible to HLO
    without scan trip-count ambiguity) and tiny smoke shapes."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (dense transformers)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array          # (B, T, KVH, D)
    v: jax.Array
    length: jax.Array     # () int32 — filled positions


def gqa_attention(params: Params, x: jax.Array, cfg, *,
                  cache: Optional[KVCache] = None,
                  positions: Optional[jax.Array] = None,
                  causal: bool = True,
                  kv_source: Optional[jax.Array] = None,
                  return_kv: bool = False,
                  ) -> Tuple[jax.Array, Optional[Any]]:
    """Multi-query/grouped-query attention with RoPE.

    Train/prefill: cache=None → blockwise attention over x itself (or
    ``kv_source`` for cross-attention); with ``return_kv`` the post-RoPE
    (k, v) come back for cache fill.  Decode: cache given, x is (B,1,D).
    """
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.compute_dtype
    xc = x.astype(dt)
    src = (kv_source if kv_source is not None else x).astype(dt)

    wq = params["wq"].astype(dt)                   # (d, H, hd)
    wk = params["wk"].astype(dt)                   # (d, KVH, hd)
    wv = params["wv"].astype(dt)
    wo = params["wo"].astype(dt)                   # (H, hd, d)
    q = jnp.einsum("bsd,dhk->bshk", xc, wq)
    k = jnp.einsum("bsd,dhk->bshk", src, wk)
    v = jnp.einsum("bsd,dhk->bshk", src, wv)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = constrain(q, "batch", None, "act_heads", None)
    k = constrain(k, "batch", None, "act_kv", None)
    v = constrain(v, "batch", None, "act_kv", None)

    use_rope = cfg.use_rope and kv_source is None
    if use_rope:
        if positions is None:
            positions = jnp.arange(s)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        # decode: append this step's k/v at cache.length
        if use_rope:
            k = apply_rope(k, positions, cfg.rope_theta)
        kfull = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                             (0, cache.length, 0, 0))
        vfull = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                             (0, cache.length, 0, 0))
        new_cache = KVCache(kfull, vfull, cache.length + s)
        krep = _repeat_kv(kfull.astype(dt), h // kvh)
        vrep = _repeat_kv(vfull.astype(dt), h // kvh)
        t = kfull.shape[1]
        logits = jnp.einsum("bshk,bthk->bhst", q, krep) / np.sqrt(hd)
        valid = jnp.arange(t)[None, None, None, :] < (cache.length + s)
        logits = jnp.where(valid, logits, -1e30)
        p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(dt)
        out = jnp.einsum("bhst,bthk->bshk", p, vrep)
    else:
        if use_rope:
            k = apply_rope(k, positions, cfg.rope_theta)
        krep = _repeat_kv(k, h // kvh)
        vrep = _repeat_kv(v, h // kvh)
        if cfg.attention_impl == "naive" or s <= 512:
            out = naive_attention(q, krep, vrep, causal=causal)
        else:
            out = blockwise_attention(q, krep, vrep, causal=causal,
                                      block_q=cfg.attn_block_q,
                                      block_kv=cfg.attn_block_kv)
        if return_kv:
            new_cache = (k, v)                      # post-RoPE, for cache fill
    out = constrain(out, "batch", None, "act_heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(dt), wo)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


class MLACache(NamedTuple):
    ckv: jax.Array        # (B, T, kv_lora)
    k_rope: jax.Array     # (B, T, rope_dim)
    length: jax.Array


def mla_attention(params: Params, x: jax.Array, cfg, *,
                  cache: Optional[MLACache] = None,
                  positions: Optional[jax.Array] = None,
                  return_kv: bool = False,
                  ) -> Tuple[jax.Array, Optional[Any]]:
    """DeepSeek-V2 MLA.  Train: reconstruct per-head K/V from the latent.
    Decode: *weight-absorbed* attention directly in latent space — the KV
    cache holds only (kv_lora + rope_dim) per token."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = cfg.compute_dtype
    xc = x.astype(dt)
    if positions is None:
        positions = jnp.arange(s)[None, :]

    # --- projections into latents ---
    cq = rms_norm(jnp.einsum("bsd,dq->bsq", xc, params["w_dq"].astype(dt)),
                  params["q_norm"]["scale"]).astype(dt)        # (B,S,q_lora)
    q = jnp.einsum("bsq,qhk->bshk", cq, params["w_uq"].astype(dt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = rms_norm(jnp.einsum("bsd,dc->bsc", xc, params["w_dkv"].astype(dt)),
                   params["kv_norm"]["scale"]).astype(dt)      # (B,S,kv_lora)
    k_rope = apply_rope(jnp.einsum("bsd,dr->bsr", xc,
                                   params["w_kr"].astype(dt))[:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0]    # (B,S,dr)
    ckv = constrain(ckv, "batch", None, None)
    scale = 1.0 / np.sqrt(dn + dr)

    if cache is None:
        # training/prefill: reconstruct K/V heads
        k_nope = jnp.einsum("bsc,chk->bshk", ckv, params["w_uk"].astype(dt))
        v = jnp.einsum("bsc,chk->bshk", ckv, params["w_uv"].astype(dt))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))],
            axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        qq = constrain(qq, "batch", None, "act_heads", None)
        k = constrain(k, "batch", None, "act_heads", None)
        if cfg.attention_impl == "naive" or s <= 512:
            out = naive_attention(qq * (scale * np.sqrt(dn + dr)), k, v, causal=True)
        else:
            out = blockwise_attention(qq, k, v, causal=True,
                                      block_q=cfg.attn_block_q,
                                      block_kv=cfg.attn_block_kv)
        new_cache = (ckv, k_rope) if return_kv else None
    else:
        # decode: absorbed attention in latent space
        ckv_full = jax.lax.dynamic_update_slice(
            cache.ckv, ckv.astype(cache.ckv.dtype), (0, cache.length, 0))
        kr_full = jax.lax.dynamic_update_slice(
            cache.k_rope, k_rope.astype(cache.k_rope.dtype), (0, cache.length, 0))
        new_cache = MLACache(ckv_full, kr_full, cache.length + s)
        t = ckv_full.shape[1]
        # absorb W_uk into q: (B,S,H,dn) x (c,h,dn) -> (B,S,H,c)
        q_abs = jnp.einsum("bshk,chk->bshc", q_nope, params["w_uk"].astype(dt))
        logits = (jnp.einsum("bshc,btc->bhst", q_abs, ckv_full.astype(dt))
                  + jnp.einsum("bshr,btr->bhst", q_rope, kr_full.astype(dt))) * scale
        valid = jnp.arange(t)[None, None, None, :] < (cache.length + s)
        logits = jnp.where(valid, logits, -1e30)
        p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(dt)
        o_lat = jnp.einsum("bhst,btc->bshc", p, ckv_full.astype(dt))
        out = jnp.einsum("bshc,chk->bshk", o_lat, params["w_uv"].astype(dt))

    out = constrain(out, "batch", None, "act_heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(dt), params["w_o"].astype(dt))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_mlp(params: Params, x: jax.Array, cfg) -> jax.Array:
    dt = cfg.compute_dtype
    xc = x.astype(dt)
    if "w3" not in params:            # 2-matrix GELU MLP (GPT-BigCode)
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", xc,
                                   params["w1"].astype(dt)))
    else:
        h = (jax.nn.silu(jnp.einsum("bsd,df->bsf", xc, params["w1"].astype(dt)))
             * jnp.einsum("bsd,df->bsf", xc, params["w3"].astype(dt)))
    h = constrain(h, "batch", None, "act_mlp")
    return jnp.einsum("bsf,fd->bsd", h, params["w2"].astype(dt))


# ---------------------------------------------------------------------------
# MoE (GShard/Switch-style capacity-based einsum dispatch)
# ---------------------------------------------------------------------------


def _expert_ffn(params: Params, xin: jax.Array, cfg, dt) -> jax.Array:
    """Expert FFN over dispatched tokens xin (G,E,C,d) → (G,E,C,d).

    Row-parallel over the DATA axis via shard_map when available (§Perf):
    expert weights are 2-D sharded (experts→model, contraction→data), so
    each chip contracts its local d/f block and psum-scatters/psums the
    activations — replacing per-layer FSDP *weight* all-gathers (expert
    weights are the bulk of a 160-expert model; gathering them per
    microbatch dominated the collective roofline term) with activation
    reductions orders of magnitude smaller.  Falls back to plain einsums
    off-mesh (CPU tests) or when dims don't divide.
    """
    from repro.parallel import sharding as sh
    mesh = sh.active_mesh()
    g, e, c, d = xin.shape
    f = cfg.d_ff

    use_tp = bool(cfg.moe_ffn_tp) and mesh is not None \
        and "data" in mesh.axis_names and "model" in mesh.axis_names
    if use_tp:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        nd, nm = sizes["data"], sizes["model"]
        bd = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        nb = int(np.prod([sizes[a] for a in bd]))
        use_tp = (d % nd == 0 and f % nd == 0 and e % nm == 0
                  and g % nb == 0)

    if not use_tp:
        w1 = params["w1"].astype(dt)
        w2 = params["w2"].astype(dt)
        w3 = params["w3"].astype(dt)
        hmid = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, w1)) \
            * jnp.einsum("gecd,edf->gecf", xin, w3)
        hmid = constrain(hmid, "batch", "act_experts", None, None)
        return jnp.einsum("gecf,efd->gecd", hmid, w2)

    from jax.sharding import PartitionSpec as P

    def body(x_l, w1_l, w3_l, w2_l):
        # tokens arrive g-sharded over data with full d; the contraction
        # dim of w1/w3 is d-sharded over data.  all_to_all rotates the
        # layout to (all local groups, d-block) so each chip contracts its
        # d-block over EVERY group, then reduce-scatters hidden into its
        # f-block (for w2) and finally reduce-scatters the output back to
        # g-sharded.  Exact; wire analysis in EXPERIMENTS §Perf It.6.
        w1c, w3c, w2c = (w.astype(dt) for w in (w1_l, w3_l, w2_l))
        x_a = jax.lax.all_to_all(x_l.astype(dt), "data", split_axis=3,
                                 concat_axis=0, tiled=True)
        h1 = jnp.einsum("gecd,edf->gecf", x_a, w1c)
        h3 = jnp.einsum("gecd,edf->gecf", x_a, w3c)
        h1 = jax.lax.psum_scatter(h1, "data", scatter_dimension=3, tiled=True)
        h3 = jax.lax.psum_scatter(h3, "data", scatter_dimension=3, tiled=True)
        h = jax.nn.silu(h1) * h3
        y = jnp.einsum("gecf,efd->gecd", h, w2c)        # partial over f
        return jax.lax.psum_scatter(y, "data", scatter_dimension=0,
                                    tiled=True)

    from repro.parallel.compat import shard_map
    bd_spec = bd if len(bd) > 1 else bd[0]
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(bd_spec, "model", None, None),
                  P("model", "data", None), P("model", "data", None),
                  P("model", "data", None)),
        out_specs=P(bd_spec, "model", None, None),
        check_vma=False)
    return fn(xin, params["w1"], params["w3"], params["w2"])


def moe_mlp(params: Params, x: jax.Array, cfg) -> jax.Array:
    """Top-k routed experts + optional shared experts (DeepSeek-V2 style).

    GShard-style *grouped* capacity dispatch: tokens are split into groups
    of ~``moe_group_size``; capacity and the one-hot dispatch/combine
    tensors are per-group, so their footprint is G·S·E·C = T·E·(S·k·f/E)
    — linear in T, not quadratic (a global-capacity dispatch tensor at
    DeepSeek scale is T·E·C ≈ 10^14 elements and cannot exist).
    Dispatch einsums are the sharding-predictable baseline; the sort-based
    path (§Perf) removes their FLOPs overhead.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    dt = cfg.compute_dtype
    t = b * s
    gsz = min(cfg.moe_group_size, t)
    assert t % gsz == 0, (t, gsz)
    g = t // gsz                                                 # groups
    xf = x.reshape(g, gsz, d).astype(dt)

    router = params["router"].astype(jnp.float32)                # (d, E)
    logits = jnp.einsum("gsd,de->gse", xf.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                         # (G,S,k)
    gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)

    cap = int(np.ceil(gsz * k / e * cfg.moe_capacity_factor))
    cap = max(cap, 4)
    # Per-slot routing with an expert-count carry — slot-major priority,
    # identical to a cumsum over the concatenated (k·S) slot-major rows,
    # but the peak intermediate is (G,S,E), not (G,k·S,E): at 236B-scale
    # prefill the fused form is what keeps multi-pod temps in HBM (§Perf).
    counts = jnp.zeros((g, 1, e), jnp.float32)       # slots used per expert
    dispatch = jnp.zeros((g, gsz, e, cap), dt)
    combine = jnp.zeros((g, gsz, e, cap), dt)
    for s_i in range(k):                                         # k small (6/8)
        oh_i = jax.nn.one_hot(idx[:, :, s_i], e, dtype=jnp.float32)  # (G,S,E)
        pos_i = jnp.cumsum(oh_i, axis=1) - oh_i + counts
        pos_a = jnp.sum(pos_i * oh_i, axis=-1)                   # (G,S)
        counts = counts + jnp.sum(oh_i, axis=1, keepdims=True)
        keep = (pos_a < cap).astype(jnp.float32)
        sel = oh_i * keep[..., None]                             # (G,S,E)
        slot = jax.nn.one_hot(pos_a, cap, dtype=jnp.float32)     # (G,S,cap)
        contrib = jnp.einsum("gse,gsc->gsec", sel, slot)
        dispatch = dispatch + contrib.astype(dt)
        combine = combine + (contrib * gates[:, :, s_i, None, None]).astype(dt)

    dispatch = constrain(dispatch, "batch", None, "act_experts", None)
    xin = jnp.einsum("gsec,gsd->gecd", dispatch, xf)             # all-to-all
    xin = constrain(xin, "batch", "act_experts", None, None)
    yexp = _expert_ffn(params, xin, cfg, dt)
    y = jnp.einsum("gecd,gsec->gsd", yexp, combine)

    if cfg.n_shared_experts:
        shared = swiglu_mlp(params["shared"], x, cfg).reshape(g, gsz, d)
        y = y + shared
    return y.reshape(b, s, d)


# ---------------------------------------------------------------------------
# RWKV-6 (Finch) — data-dependent decay linear attention
# ---------------------------------------------------------------------------


def _segment_size(s: int, target: int) -> int:
    """Largest divisor of ``s`` that is ≤ ``target`` (recurrence chunking
    must tile the sequence exactly; odd lengths fall back to smaller tiles)."""
    seg = max(1, min(target, s))
    while s % seg:
        seg -= 1
    return seg


def token_shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """Shift sequence right by one; ``prev`` is the carry token for decode."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None, :], x[:, :-1]], axis=1) if x.shape[1] > 1 else prev[:, None, :]


def _rwkv_mix(params, x, xs, name, dt):
    """ddlerp: x + (xs - x) * (mu + lora(x))  (RWKV6 data-dependent lerp)."""
    mu = params[f"mu_{name}"].astype(dt)
    lo = jnp.tanh(jnp.einsum("bsd,dr->bsr", x, params["lora_A"].astype(dt)))
    dd = jnp.einsum("bsr,rd->bsd", lo, params[f"lora_B_{name}"].astype(dt))
    return x + (xs - x) * (mu + dd)


class RWKVState(NamedTuple):
    wkv: jax.Array        # (B, H, D, D) f32
    shift_t: jax.Array    # (B, d) last token (time-mix)
    shift_c: jax.Array    # (B, d) last token (channel-mix)


def wkv_chunked(r, k, v, lw, u, S0, chunk: int):
    """Chunked WKV6 — the TPU-native reformulation of the token-serial
    recurrence (the RWKV CUDA kernel's job, recast as MXU matmuls).

    Within a segment of C tokens the linear recurrence
        S_{t+1} = diag(w_t) S_t + k_t ⊗ v_t,   out_t = r_t·(S_t + u⊙k_t⊗v_t)
    unrolls to  out_t = (r_t⊙exp(P_{t-1}))·S_0
               + Σ_{s<t} [(r_t⊙exp(P_{t-1}))·(k_s⊙exp(-P_s))] v_s
               + (r_t·(u⊙k_t)) v_t,       P_t = Σ_{τ≤t} log w_τ,
    i.e. ONE (C,C) masked matmul per segment plus a state matmul — HBM
    traffic drops ~C× and the work lands on the MXU.  exp(±P) stays in f32
    range for C·|log w| ≲ 87 (enforced by the caller's clip on log w).

    r/k/v/lw: (B,S,H,D) f32 (lw = log w < 0);  u: (H,D);  S0: (B,H,D,D).
    Returns (out (B,S,H,D), S_end).
    """
    b, s, h, d = r.shape
    c = _segment_size(s, chunk)
    n = s // c
    seg = lambda z: z.reshape(b, n, c, h, d).transpose(1, 0, 3, 2, 4)
    rs, ks, vs, ls = seg(r), seg(k), seg(v), seg(lw)   # (n,B,H,C,D)
    tidx = jnp.arange(c)
    mask = (tidx[:, None] > tidx[None, :])[None, None]   # strictly causal

    @jax.checkpoint
    def body(S, xs):
        rc, kc, vc, lc = xs                        # (B,H,C,D)
        P = jnp.cumsum(lc, axis=2)                 # inclusive prefix logsum
        Qs = jnp.exp(jnp.pad(P, ((0, 0), (0, 0), (1, 0), (0, 0)))[:, :, :-1])
        rq = rc * Qs                               # r_t ⊙ exp(P_{t-1})
        ka = kc * jnp.exp(-P)                      # k_s ⊙ exp(-P_s)
        att = jnp.einsum("bhtd,bhsd->bhts", rq, ka)
        att = jnp.where(mask, att, 0.0)
        du = jnp.sum(rc * u[None, :, None, :] * kc, axis=-1)   # diag (u) term
        out = (jnp.einsum("bhts,bhsd->bhtd", att, vc)
               + du[..., None] * vc
               + jnp.einsum("bhtd,bhdv->bhtv", rq, S))
        decay = jnp.exp(P[:, :, -1])               # (B,H,D) total decay
        kb = ka * decay[:, :, None, :]             # k_s ⊙ exp(P_{C-1}-P_s)
        S = decay[..., None] * S + jnp.einsum("bhtd,bhtv->bhdv", kb, vc)
        return S, out

    S1, outs = jax.lax.scan(body, S0, (rs, ks, vs, ls))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, d)
    return out, S1


def rwkv6_time_mix(params: Params, x: jax.Array, cfg,
                   state: Optional[RWKVState] = None
                   ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """WKV6 recurrence: S_{t+1} = diag(w_t) S_t + k_t ⊗ v_t,
    out_t = r_t · (S_t + diag(u) k_t ⊗ v_t); w_t data-dependent."""
    b, s, d = x.shape
    hn, hd = cfg.n_heads, cfg.hd
    dt = cfg.compute_dtype
    xc = x.astype(dt)

    prev = state.shift_t if state is not None else None
    xs = token_shift(xc, prev)
    xr = _rwkv_mix(params, xc, xs, "r", dt)
    xk = _rwkv_mix(params, xc, xs, "k", dt)
    xv = _rwkv_mix(params, xc, xs, "v", dt)
    xw = _rwkv_mix(params, xc, xs, "w", dt)
    xg = _rwkv_mix(params, xc, xs, "g", dt)

    r = jnp.einsum("bsd,de->bse", xr, params["w_r"].astype(dt)).reshape(b, s, hn, hd)
    kk = jnp.einsum("bsd,de->bse", xk, params["w_k"].astype(dt)).reshape(b, s, hn, hd)
    vv = jnp.einsum("bsd,de->bse", xv, params["w_v"].astype(dt)).reshape(b, s, hn, hd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, params["w_g"].astype(dt)))
    # data-dependent decay (the Finch feature): w in (0,1), f32
    dd = jnp.einsum("bsr,re->bse",
                    jnp.tanh(jnp.einsum("bsd,dr->bsr", xw.astype(jnp.float32),
                                        params["wlora_A"].astype(jnp.float32))),
                    params["wlora_B"].astype(jnp.float32))
    wlog = (params["w0"].astype(jnp.float32)
            + params["w_bias"].astype(jnp.float32) + dd)
    w = jnp.exp(-jnp.exp(jnp.clip(wlog, -8.0, 1.0))).reshape(b, s, hn, hd)
    u = params["u"].astype(jnp.float32)                         # (H, D)

    r = constrain(r, "batch", None, "act_heads", None)
    kk = constrain(kk, "batch", None, "act_heads", None)
    vv = constrain(vv, "batch", None, "act_heads", None)

    rf, kf, vf = (z.astype(jnp.float32) for z in (r, kk, vv))

    def step(S, inputs):
        rt, kt, vt, wt = inputs                     # (B,H,D) each
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)    # (B,H,D,D)
        out = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, out

    S0 = (state.wkv if state is not None
          else jnp.zeros((b, hn, hd, hd), jnp.float32))

    if s == 1:
        S1, out = step(S0, (rf[:, 0].transpose(0, 1, 2), kf[:, 0], vf[:, 0],
                            w[:, 0].astype(jnp.float32)))
        outs = out[:, None]
    elif cfg.wkv_impl == "chunked":
        # chunked clip keeps C·|log w| inside f32 exp range (see wkv_chunked)
        lw = (-jnp.exp(jnp.clip(wlog, -8.0, 0.9))).reshape(b, s, hn, hd)
        outs, S1 = wkv_chunked(rf, kf, vf, lw, u, S0, cfg.wkv_chunk)
    else:
        seg = _segment_size(s, cfg.ssm_segment)
        nseg = s // seg

        @jax.checkpoint
        def seg_body(S, xs_seg):
            rs, ks, vs, ws = xs_seg                 # (seg, B, H, D)
            S2, outs = jax.lax.scan(step, S, (rs, ks, vs, ws))
            return S2, outs

        def outer(S, xs_seg):
            return seg_body(S, xs_seg)

        resh = lambda z: z.astype(jnp.float32).reshape(b, nseg, seg, hn, hd).transpose(1, 2, 0, 3, 4)
        S1, outs = jax.lax.scan(outer, S0,
                                (resh(rf), resh(kf), resh(vf), resh(w)))
        outs = outs.reshape(nseg * seg, b, hn, hd).transpose(1, 0, 2, 3)

    out = outs.reshape(b, s, hn * hd).astype(dt)
    out = rms_norm(out.reshape(b, s, hn, hd),
                   params["ln_x"]["scale"].reshape(hn, hd)).reshape(b, s, d)
    out = out.astype(dt) * g
    y = jnp.einsum("bse,ed->bsd", out, params["w_o"].astype(dt))
    new_shift = xc[:, -1] if state is not None else None
    return y, (S1, new_shift)


def rwkv6_channel_mix(params: Params, x: jax.Array, cfg,
                      prev: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, Optional[jax.Array]]:
    dt = cfg.compute_dtype
    xc = x.astype(dt)
    xs = token_shift(xc, prev)
    mu_k = params["mu_ck"].astype(dt)
    mu_r = params["mu_cr"].astype(dt)
    xk = xc + (xs - xc) * mu_k
    xr = xc + (xs - xc) * mu_r
    kk = jnp.einsum("bsd,df->bsf", xk, params["w_ck"].astype(dt))
    kk = jnp.square(jax.nn.relu(kk))
    kk = constrain(kk, "batch", None, "act_mlp")
    kv = jnp.einsum("bsf,fd->bsd", kk, params["w_cv"].astype(dt))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["w_cr"].astype(dt)))
    new_prev = xc[:, -1] if prev is not None else None
    return rr * kv, new_prev


# ---------------------------------------------------------------------------
# Mamba2 (SSD) — for the Zamba2 hybrid
# ---------------------------------------------------------------------------


class MambaState(NamedTuple):
    ssm: jax.Array        # (B, H, P, N) f32
    conv: jax.Array       # (B, conv_k-1, d_inner)


def ssd_chunked(xbar, la, b_t, c_t, h0, chunk: int):
    """Chunked SSD (Mamba2's own block decomposition) — scalar-per-head
    decay makes this the easy case of ``wkv_chunked``:

        h_t = a_t h_{t-1} + x̄_t ⊗ b_t,   y_t = h_t · c_t
      ⇒ y_t = exp(P_t)(c_t·h_0) + Σ_{s≤t} exp(P_t−P_s)(c_t·b_s) x̄_s

    with P_t = Σ_{τ≤t} log a_τ per (batch, head) — the decay matrix
    exp(P_t−P_s) is a cheap (C,C) scalar outer term (always ≤ 1: no
    f32-range concerns), and the rest is two matmuls per segment.

    xbar: (B,S,H,Pdim) f32;  la = log a: (B,S,H);  b_t/c_t: (B,S,N);
    h0: (B,H,Pdim,N).  Returns (y (B,S,H,Pdim), h_end).
    """
    B, S, H, Pd = xbar.shape
    N = b_t.shape[-1]
    c = _segment_size(S, chunk)
    n = S // c
    seg4 = lambda z: z.reshape(B, n, c, H, Pd).transpose(1, 0, 3, 2, 4)
    segA = lambda z: z.reshape(B, n, c, H).transpose(1, 0, 3, 2)   # (n,B,H,C)
    segN = lambda z: z.reshape(B, n, c, N).transpose(1, 0, 2, 3)   # (n,B,C,N)
    xs, las = seg4(xbar), segA(la)
    bs, cs = segN(b_t), segN(c_t)
    tidx = jnp.arange(c)
    causal = (tidx[:, None] >= tidx[None, :])[None, None]          # s ≤ t

    @jax.checkpoint
    def body(h, inp):
        xc, lc, bc, cc = inp            # (B,H,C,P) (B,H,C) (B,C,N) (B,C,N)
        P_ = jnp.cumsum(lc, axis=2)                                # (B,H,C)
        decay = jnp.exp(P_[:, :, :, None] - P_[:, :, None, :])     # (B,H,C,C)
        cb = jnp.einsum("btn,bsn->bts", cc, bc)                    # (B,C,C)
        att = jnp.where(causal, decay * cb[:, None], 0.0)
        y = jnp.einsum("bhts,bhsp->bhtp", att, xc)
        y = y + jnp.exp(P_)[..., None] * jnp.einsum(
            "btn,bhpn->bhtp", cc, h)
        dtot = jnp.exp(P_[:, :, -1])                               # (B,H)
        w = jnp.exp(P_[:, :, -1:] - P_)                            # (B,H,C)
        h = dtot[..., None, None] * h + jnp.einsum(
            "bhsp,bsn,bhs->bhpn", xc, bc, w)
        return h, y

    h1, ys = jax.lax.scan(body, h0, (xs, las, bs, cs))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, Pd)
    return y, h1


def _causal_conv(x: jax.Array, w: jax.Array, prev: Optional[jax.Array]):
    """Depthwise causal conv, kernel K: x (B,S,C), w (K,C)."""
    k = w.shape[0]
    if prev is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([prev, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    new_prev = xp[:, -(k - 1):] if prev is not None else None
    return out, new_prev


def mamba2_block(params: Params, x: jax.Array, cfg,
                 state: Optional[MambaState] = None
                 ) -> Tuple[jax.Array, Optional[MambaState]]:
    """Mamba2 SSD: scalar-per-head decay, state (H, P, N)."""
    b, s, d = x.shape
    di, hn, pn, nn = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    dt_ = cfg.compute_dtype
    xc = x.astype(dt_)

    proj = jnp.einsum("bsd,dz->bsz", xc, params["in_proj"].astype(dt_))
    z, xin, bc, dtp = jnp.split(proj, [di, 2 * di, 2 * di + 2 * nn], axis=-1)
    xin = constrain(xin, "batch", None, "act_mlp")
    z = constrain(z, "batch", None, "act_mlp")
    prev_conv = state.conv if state is not None else None
    xin, new_conv = _causal_conv(xin, params["conv_w"].astype(dt_), prev_conv)
    xin = jax.nn.silu(xin)
    b_t, c_t = bc[..., :nn].astype(jnp.float32), bc[..., nn:].astype(jnp.float32)
    dt_t = jax.nn.softplus(dtp.astype(jnp.float32)
                           + params["dt_bias"].astype(jnp.float32))   # (B,S,H)
    a = jnp.exp(-dt_t * jnp.exp(params["a_log"].astype(jnp.float32)))  # (B,S,H)

    xh = xin.reshape(b, s, hn, pn).astype(jnp.float32)
    xbar = xh * dt_t[..., None]

    def step(h, inputs):
        at, xt, bt, ct = inputs                     # (B,H) (B,H,P) (B,N) (B,N)
        h = h * at[..., None, None] + jnp.einsum("bhp,bn->bhpn", xt, bt)
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = (state.ssm if state is not None
          else jnp.zeros((b, hn, pn, nn), jnp.float32))

    if s == 1:
        h1, y = step(h0, (a[:, 0], xbar[:, 0], b_t[:, 0], c_t[:, 0]))
        ys = y[:, None]
    elif cfg.ssm_impl == "chunked":
        la = -(dt_t * jnp.exp(params["a_log"].astype(jnp.float32)))  # log a
        y_c, h1 = ssd_chunked(xbar, la, b_t, c_t, h0, cfg.ssd_chunk)
        # match the serial path's output layout (B,S,H,P) — reuse directly
        ys = y_c
    else:
        seg = _segment_size(s, cfg.ssm_segment)
        nseg = s // seg

        @jax.checkpoint
        def seg_body(h, xs_seg):
            return jax.lax.scan(step, h, xs_seg)

        tseq = lambda z: z.reshape((b, nseg, seg) + z.shape[2:]).transpose(
            (1, 2, 0) + tuple(range(3, z.ndim + 1)))
        h1, ys = jax.lax.scan(lambda h, xs_: seg_body(h, xs_), h0,
                              (tseq(a), tseq(xbar), tseq(b_t), tseq(c_t)))
        ys = ys.reshape((nseg * seg, b, hn, pn)).transpose(1, 0, 2, 3)

    y = ys + xh * params["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di).astype(dt_)
    y = rms_norm(y, params["out_norm"]["scale"]) * jax.nn.silu(z)
    out = jnp.einsum("bsz,zd->bsd", y.astype(dt_), params["out_proj"].astype(dt_))
    new_state = None
    if state is not None:
        new_state = MambaState(ssm=h1, conv=new_conv)
    return out, new_state
