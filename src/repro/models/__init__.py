"""Model definitions for all assigned architectures."""
from repro.models.api import Model, build_model
