"""Family assemblies: decoder-only (dense/MoE/VLM), enc-dec, RWKV6, hybrid.

All families expose the same functional surface (see ``api.Model``):
  loss(params, batch)                    one microbatch, scalar
  prefill(params, batch) -> (cache, logits_last)
  decode_step(params, cache, tokens) -> (logits, cache)

Layer parameters are stacked on a leading "layers" axis and applied with
``lax.scan`` (+ per-block remat) so the HLO stays one-block-sized for 80-layer
models — dry-run compile time and analyzability depend on this.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import spec as S
from repro.models.spec import p
from repro.parallel.sharding import constrain

# ===========================================================================
# Param specs
# ===========================================================================


def _norm_spec(cfg, d=None):
    if cfg.norm == "nonparam_ln":
        return {}
    return {"scale": p((d or cfg.d_model,), ("embed",), init="ones")}


def _attn_specs(cfg) -> Dict[str, Any]:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    out = {
        "wq": p((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": p((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wv": p((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wo": p((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        out["bq"] = p((h, hd), ("heads", "head_dim"), init="zeros")
        out["bk"] = p((kvh, hd), ("kv_heads", "head_dim"), init="zeros")
        out["bv"] = p((kvh, hd), ("kv_heads", "head_dim"), init="zeros")
    return out


def _mla_specs(cfg) -> Dict[str, Any]:
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_dq": p((d, cfg.q_lora), ("embed", "lora")),
        "q_norm": {"scale": p((cfg.q_lora,), ("lora",), init="ones")},
        "w_uq": p((cfg.q_lora, h, dn + dr), ("lora", "heads", "head_dim")),
        "w_dkv": p((d, cfg.kv_lora), ("embed", "lora")),
        "kv_norm": {"scale": p((cfg.kv_lora,), ("lora",), init="ones")},
        "w_kr": p((d, dr), ("embed", "head_dim")),
        "w_uk": p((cfg.kv_lora, h, dn), ("lora", "heads", "head_dim")),
        "w_uv": p((cfg.kv_lora, h, dv), ("lora", "heads", "head_dim")),
        "w_o": p((h, dv, d), ("heads", "head_dim", "embed")),
    }


def _mlp_specs(cfg, d_ff=None) -> Dict[str, Any]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    out = {
        "w1": p((d, f), ("embed", "mlp")),
        "w2": p((f, d), ("mlp", "embed")),
    }
    if cfg.mlp_kind == "swiglu":
        out["w3"] = p((d, f), ("embed", "mlp"))
    return out


def _moe_specs(cfg) -> Dict[str, Any]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff
    out = {
        "router": p((d, e), ("embed", "experts")),
        # 2-D expert sharding: experts over `model`, the CONTRACTED dim of
        # each matmul over `data` (w1/w3: d; w2: f) so the shard_map
        # row-parallel path (layers._expert_ffn) contracts shard-locally
        # and psums activations instead of gathering weights.
        "w1": p((e, d, f), ("experts", "embed", "expert_mlp")),
        "w3": p((e, d, f), ("experts", "embed", "expert_mlp")),
        "w2": p((e, f, d), ("experts", "expert_ffn", "embed")),
    }
    if cfg.n_shared_experts:
        out["shared"] = _mlp_specs(cfg, d_ff=cfg.n_shared_experts * f)
    return out


def _dense_block_specs(cfg) -> Dict[str, Any]:
    blk = {
        "attn_norm": _norm_spec(cfg),
        "mlp_norm": _norm_spec(cfg),
        "attn": _mla_specs(cfg) if cfg.use_mla else _attn_specs(cfg),
    }
    blk["mlp"] = _moe_specs(cfg) if cfg.family == "moe" else _mlp_specs(cfg)
    return blk


def _rwkv_block_specs(cfg) -> Dict[str, Any]:
    d, r = cfg.d_model, cfg.rwkv_lora
    hn, hd = cfg.n_heads, cfg.hd
    tm = {
        "lora_A": p((d, r), ("embed", "lora")),
        "w0": p((d,), ("embed",), init="zeros"),
        "wlora_A": p((d, r), ("embed", "lora")),
        "wlora_B": p((r, d), ("lora", "embed"), init="small"),
        "w_bias": p((d,), ("embed",), init="zeros"),
        # literal head-count dim (40 for rwkv6-3b): tiny — keep replicated
        # so it never constrains mesh divisibility
        "u": p((hn, hd), ("null", "head_dim")),
        "w_r": p((d, d), ("embed", "heads")),
        "w_k": p((d, d), ("embed", "heads")),
        "w_v": p((d, d), ("embed", "heads")),
        "w_g": p((d, d), ("embed", "heads")),
        "w_o": p((d, d), ("heads", "embed")),
        "ln_x": {"scale": p((d,), ("embed",), init="ones")},
    }
    for name in ("r", "k", "v", "w", "g"):
        tm[f"mu_{name}"] = p((d,), ("embed",), init="zeros")
        tm[f"lora_B_{name}"] = p((r, d), ("lora", "embed"), init="small")
    cm = {
        "mu_ck": p((d,), ("embed",), init="zeros"),
        "mu_cr": p((d,), ("embed",), init="zeros"),
        "w_ck": p((d, cfg.d_ff), ("embed", "mlp")),
        "w_cv": p((cfg.d_ff, d), ("mlp", "embed")),
        "w_cr": p((d, d), ("embed", "heads")),
    }
    return {"tm_norm": _norm_spec(cfg), "cm_norm": _norm_spec(cfg),
            "time_mix": tm, "channel_mix": cm}


def _mamba_block_specs(cfg) -> Dict[str, Any]:
    d, di, hn, nn = cfg.d_model, cfg.d_inner, cfg.ssm_heads, cfg.ssm_state
    z = 2 * di + 2 * nn + hn
    return {
        "norm": _norm_spec(cfg),
        "in_proj": p((d, z), ("embed", "mlp")),
        "conv_w": p((cfg.conv_k, di), ("conv", "mlp"), init="small"),
        "dt_bias": p((hn,), ("heads",), init="zeros"),
        "a_log": p((hn,), ("heads",), init="zeros"),
        "d_skip": p((hn,), ("heads",), init="ones"),
        "out_norm": {"scale": p((di,), ("mlp",), init="ones")},
        "out_proj": p((di, d), ("mlp", "embed")),
    }


def _stack(n: int, tree):
    return S.map_axes(tree, lambda s: S.ParamSpec(
        (n,) + s.shape, ("layers",) + s.axes, s.init, s.scale, s.dtype))


def param_specs(cfg) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_padded
    out: Dict[str, Any] = {
        "embed": p((v, d), ("vocab", "embed"), init="embed"),
        "lm_head": p((d, v), ("embed", "vocab")),
        "final_norm": _norm_spec(cfg),
    }
    if cfg.family in ("dense", "moe", "vlm"):
        out["blocks"] = _stack(cfg.n_layers, _dense_block_specs(cfg))
    elif cfg.family == "rwkv":
        out["blocks"] = _stack(cfg.n_layers, _rwkv_block_specs(cfg))
    elif cfg.family == "hybrid":
        out["blocks"] = _stack(cfg.n_layers, _mamba_block_specs(cfg))
        shared_cfg = cfg.replace(family="dense")
        out["shared_attn"] = _dense_block_specs(shared_cfg)
    elif cfg.family == "encdec":
        out["enc_blocks"] = _stack(cfg.n_enc_layers, _dense_block_specs(cfg))
        dec = _dense_block_specs(cfg)
        dec["cross_attn"] = _attn_specs(cfg)
        dec["cross_norm"] = _norm_spec(cfg)
        out["blocks"] = _stack(cfg.n_layers, dec)
    else:
        raise ValueError(cfg.family)
    return out


# ===========================================================================
# Block forwards (uniform signature)
# ===========================================================================


def dense_block(lp, x, cfg, *, cache=None, positions=None, causal=True,
                cross_kv=None, return_kv=False):
    """Pre-norm transformer block; returns (x, new_cache)."""
    h = L.apply_norm(lp, "attn_norm", x, cfg.norm)
    # plain tuples wrap (attn_cache, ...); NamedTuple caches pass through
    is_plain_tuple = isinstance(cache, tuple) and not hasattr(cache, "_fields")
    attn_cache = cache[0] if is_plain_tuple else cache
    if cfg.use_mla:
        a, new_cache = L.mla_attention(lp["attn"], h, cfg, cache=attn_cache,
                                       positions=positions)
    else:
        a, new_cache = L.gqa_attention(lp["attn"], h, cfg, cache=attn_cache,
                                       positions=positions, causal=causal)
    x = x + a
    if cross_kv is not None:
        h = L.apply_norm(lp, "cross_norm", x, cfg.norm)
        c, _ = L.gqa_attention(lp["cross_attn"], h, cfg, kv_source=cross_kv,
                               causal=False)
        x = x + c
    h = L.apply_norm(lp, "mlp_norm", x, cfg.norm)
    if cfg.family == "moe":
        m = L.moe_mlp(lp["mlp"], h, cfg)
    else:
        m = L.swiglu_mlp(lp["mlp"], h, cfg)
    x = x + m
    x = constrain(x, "batch", "seq_sp", None)
    return x, new_cache


def rwkv_block(lp, x, cfg, *, state=None):
    h = L.apply_norm(lp, "tm_norm", x, cfg.norm)
    tm_state = None
    if state is not None:
        tm_state = L.RWKVState(wkv=state["wkv"], shift_t=state["shift_t"],
                               shift_c=state["shift_c"])
    a, (wkv1, shift1) = L.rwkv6_time_mix(lp["time_mix"], h, cfg, state=tm_state)
    x = x + a
    h = L.apply_norm(lp, "cm_norm", x, cfg.norm)
    prev_c = state["shift_c"] if state is not None else None
    m, shift_c1 = L.rwkv6_channel_mix(lp["channel_mix"], h, cfg, prev=prev_c)
    x = x + m
    x = constrain(x, "batch", "seq_sp", None)
    new_state = None
    if state is not None:
        new_state = {"wkv": wkv1, "shift_t": shift1, "shift_c": shift_c1}
    return x, new_state


def mamba_block(lp, x, cfg, *, state=None):
    h = L.apply_norm(lp, "norm", x, cfg.norm)
    if isinstance(state, dict):
        state = L.MambaState(ssm=state["ssm"], conv=state["conv"])
    m, new_state = L.mamba2_block(lp, h, cfg, state=state)
    x = x + m
    x = constrain(x, "batch", "seq_sp", None)
    return x, new_state


# ===========================================================================
# Stacks (scan over layers)
# ===========================================================================


def _scan_blocks(blocks, x, block_fn, remat=True):
    f = jax.checkpoint(block_fn) if remat else block_fn

    def body(h, lp):
        h2, _ = f(lp, h)
        return h2, None

    x, _ = jax.lax.scan(body, x, blocks)
    return x


def _scan_blocks_cache(blocks, x, caches, block_fn):
    """Decode/prefill scan: caches stacked on leading layer axis."""
    def body(h, xs):
        lp, c = xs
        h2, c2 = block_fn(lp, h, c)
        return h2, c2

    x, new_caches = jax.lax.scan(body, x, (blocks, caches))
    return x, new_caches


# ===========================================================================
# Losses
# ===========================================================================


def lm_loss_from_hidden(params, hidden, targets, mask, cfg):
    """Chunked softmax CE — never materializes (B, S, V) at once."""
    b, s_len, d = hidden.shape
    c = L._segment_size(s_len, cfg.loss_chunk)
    n = s_len // c
    w = params["lm_head"]
    dt = cfg.compute_dtype

    @jax.checkpoint
    def chunk(carry, xs):
        h, t, m = xs                                # (B,c,d) (B,c) (B,c)
        logits = jnp.einsum("bcd,dv->bcv", h.astype(dt), w.astype(dt))
        logits = constrain(logits.astype(jnp.float32), "batch", None, "act_vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        # NOTE(§Perf, refuted): replacing this gather with a where+iota
        # masked reduction did NOT change the lowered collectives (XLA
        # already handles the sharded-vocab gather) and cost +1GiB of
        # materialized iota — keep the straightforward form.
        ll = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        loss_sum, tok_sum = carry
        return (loss_sum + jnp.sum((lse - ll) * m), tok_sum + jnp.sum(m)), None

    resh = lambda z: z.reshape((b, n, c) + z.shape[2:]).swapaxes(0, 1)
    (loss_sum, tok_sum), _ = jax.lax.scan(
        chunk, (jnp.float32(0), jnp.float32(0)),
        (resh(hidden), resh(targets), resh(mask)))
    return loss_sum / jnp.maximum(tok_sum, 1.0)


def logits_last(params, hidden, cfg):
    """Logits of the final position only (serving)."""
    dt = cfg.compute_dtype
    h = hidden[:, -1:]
    logits = jnp.einsum("bcd,dv->bcv", h.astype(dt),
                        params["lm_head"].astype(dt))
    return logits.astype(jnp.float32)


# ===========================================================================
# Family forward passes
# ===========================================================================


def _embed(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    return x * np.sqrt(cfg.d_model)


def decoder_hidden(params, tokens, cfg, *, patches=None, remat=None):
    """Decoder-only trunk (dense/moe/vlm). Returns final-norm hidden."""
    x = _embed(params, tokens, cfg)
    if patches is not None:                         # VLM: prepend patch embeds
        x = jnp.concatenate([patches.astype(cfg.compute_dtype), x], axis=1)
    x = constrain(x, "batch", "seq_sp", None)
    block = functools.partial(dense_block, cfg=cfg)
    x = _scan_blocks(params["blocks"], x, lambda lp, h: block(lp, h),
                     remat=cfg.remat if remat is None else remat)
    return L.apply_norm(params, "final_norm", x, cfg.norm)


def rwkv_hidden(params, tokens, cfg, *, remat=None):
    x = _embed(params, tokens, cfg)
    x = constrain(x, "batch", "seq_sp", None)
    x = _scan_blocks(params["blocks"], x,
                     lambda lp, h: rwkv_block(lp, h, cfg),
                     remat=cfg.remat if remat is None else remat)
    return L.apply_norm(params, "final_norm", x, cfg.norm)


def hybrid_hidden(params, tokens, cfg, *, remat=None):
    """Zamba2: groups of Mamba2 layers with a shared attention block between."""
    x = _embed(params, tokens, cfg)
    x = constrain(x, "batch", "seq_sp", None)
    shared_cfg = cfg.replace(family="dense")
    use_remat = cfg.remat if remat is None else remat
    every = cfg.shared_attn_every
    n = cfg.n_layers
    for g0 in range(0, n, every):
        g1 = min(g0 + every, n)
        seg = jax.tree.map(lambda a: a[g0:g1], params["blocks"])
        x = _scan_blocks(seg, x, lambda lp, h: mamba_block(lp, h, cfg),
                         remat=use_remat)
        if g1 < n:
            blk = functools.partial(dense_block, cfg=shared_cfg)
            f = jax.checkpoint(lambda lp, h: blk(lp, h)) if use_remat else (
                lambda lp, h: blk(lp, h))
            x, _ = f(params["shared_attn"], x)
    return L.apply_norm(params, "final_norm", x, cfg.norm)


def encdec_hidden(params, frames, tokens, cfg, *, remat=None):
    """Seamless: encoder over frame embeddings, causal decoder w/ cross-attn."""
    use_remat = cfg.remat if remat is None else remat
    enc = frames.astype(cfg.compute_dtype)
    enc = constrain(enc, "batch", "seq_sp", None)
    enc = _scan_blocks(params["enc_blocks"], enc,
                       lambda lp, h: dense_block(lp, h, cfg, causal=False),
                       remat=use_remat)
    enc = L.apply_norm(params, "final_norm", enc, cfg.norm)

    x = _embed(params, tokens, cfg)
    x = constrain(x, "batch", "seq_sp", None)
    block = lambda lp, h: dense_block(lp, h, cfg, cross_kv=enc)
    x = _scan_blocks(params["blocks"], x, block, remat=use_remat)
    return L.apply_norm(params, "final_norm", x, cfg.norm)


def family_hidden(params, batch, cfg, *, remat=None):
    if cfg.family in ("dense", "moe"):
        return decoder_hidden(params, batch["tokens"], cfg, remat=remat)
    if cfg.family == "vlm":
        return decoder_hidden(params, batch["tokens"], cfg,
                              patches=batch["patches"], remat=remat)
    if cfg.family == "rwkv":
        return rwkv_hidden(params, batch["tokens"], cfg, remat=remat)
    if cfg.family == "hybrid":
        return hybrid_hidden(params, batch["tokens"], cfg, remat=remat)
    if cfg.family == "encdec":
        return encdec_hidden(params, batch["frames"], batch["tokens"], cfg,
                             remat=remat)
    raise ValueError(cfg.family)


def loss_fn(params, batch, cfg):
    """One-microbatch LM loss."""
    hidden = family_hidden(params, batch, cfg)
    targets, mask = batch["targets"], batch["mask"]
    if cfg.family == "vlm":
        # hidden includes patch positions; loss only over text positions
        pad = jnp.zeros((targets.shape[0], cfg.n_patches), targets.dtype)
        mpad = jnp.zeros((targets.shape[0], cfg.n_patches), mask.dtype)
        targets = jnp.concatenate([pad, targets], axis=1)
        mask = jnp.concatenate([mpad, mask], axis=1)
    return lm_loss_from_hidden(params, hidden, targets, mask, cfg)
