"""Serving paths: prefill (fill caches at O(S) memory) and decode_step.

Caches are plain dict pytrees stacked on a leading "layers" axis so decode
scans over (block_params, cache) pairs — one block of HLO regardless of
depth.  All cache buffers carry logical sharding axes; for batch=1 long-
context shapes the launch layer swaps rules to shard the cache *sequence*
axis instead (flash-decode style, DESIGN §5/§6).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import lm
from repro.parallel.sharding import constrain

# ===========================================================================
# Cache construction
# ===========================================================================


def cache_struct(cfg, batch: int, max_len: int) -> Dict[str, Any]:
    """ShapeDtypeStruct tree of the decode cache (also used to allocate)."""
    ct = cfg.compute_dtype
    ln, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    sds = jax.ShapeDtypeStruct
    if cfg.family in ("dense", "vlm") or (cfg.family == "moe" and not cfg.use_mla):
        return {"k": sds((ln, batch, max_len, kvh, hd), ct),
                "v": sds((ln, batch, max_len, kvh, hd), ct),
                "length": sds((ln,), jnp.int32)}
    if cfg.family == "moe" and cfg.use_mla:
        return {"ckv": sds((ln, batch, max_len, cfg.kv_lora), ct),
                "k_rope": sds((ln, batch, max_len, cfg.qk_rope_dim), ct),
                "length": sds((ln,), jnp.int32)}
    if cfg.family == "rwkv":
        return {"wkv": sds((ln, batch, cfg.n_heads, cfg.hd, cfg.hd), jnp.float32),
                "shift_t": sds((ln, batch, cfg.d_model), ct),
                "shift_c": sds((ln, batch, cfg.d_model), ct)}
    if cfg.family == "hybrid":
        n_apps = max(1, (cfg.n_layers - 1) // cfg.shared_attn_every)
        return {
            "mamba": {
                "ssm": sds((ln, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.float32),
                "conv": sds((ln, batch, cfg.conv_k - 1, cfg.d_inner), ct)},
            "attn": {"k": sds((n_apps, batch, max_len, kvh, hd), ct),
                     "v": sds((n_apps, batch, max_len, kvh, hd), ct),
                     "length": sds((n_apps,), jnp.int32)},
        }
    if cfg.family == "encdec":
        enc_len = max_len   # encoder length == prefill length for this bench
        return {"self": {"k": sds((ln, batch, max_len, kvh, hd), ct),
                         "v": sds((ln, batch, max_len, kvh, hd), ct),
                         "length": sds((ln,), jnp.int32)},
                "cross_k": sds((ln, batch, enc_len, kvh, hd), ct),
                "cross_v": sds((ln, batch, enc_len, kvh, hd), ct)}
    raise ValueError(cfg.family)


def cache_axes(cfg) -> Dict[str, Any]:
    """Logical axis names mirroring cache_struct (for shardings)."""
    kv = ("layers", "cache_batch", "cache_seq", "cache_heads", "head_dim")
    if cfg.family in ("dense", "vlm") or (cfg.family == "moe" and not cfg.use_mla):
        return {"k": kv, "v": kv, "length": ("layers",)}
    if cfg.family == "moe" and cfg.use_mla:
        return {"ckv": ("layers", "cache_batch", "cache_seq", None),
                "k_rope": ("layers", "cache_batch", "cache_seq", None),
                "length": ("layers",)}
    if cfg.family == "rwkv":
        return {"wkv": ("layers", "cache_batch", "cache_heads", None, None),
                "shift_t": ("layers", "cache_batch", None),
                "shift_c": ("layers", "cache_batch", None)}
    if cfg.family == "hybrid":
        return {"mamba": {"ssm": ("layers", "cache_batch", "cache_heads", None, None),
                          "conv": ("layers", "cache_batch", None, "act_mlp")},
                "attn": {"k": kv, "v": kv, "length": ("layers",)}}
    if cfg.family == "encdec":
        return {"self": {"k": kv, "v": kv, "length": ("layers",)},
                "cross_k": kv, "cross_v": kv}
    raise ValueError(cfg.family)


def init_cache(cfg, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_struct(cfg, batch, max_len))


# ===========================================================================
# Prefill
# ===========================================================================


def _pad_time(kv: jax.Array, max_len: int, axis: int = 2) -> jax.Array:
    pad = [(0, 0)] * kv.ndim
    pad[axis] = (0, max_len - kv.shape[axis])
    return jnp.pad(kv, pad)


def prefill(params, batch, cfg, max_len: Optional[int] = None):
    """Process the prompt, return (cache, last-position logits)."""
    s = batch["tokens"].shape[1]
    if cfg.family == "vlm":
        s += cfg.n_patches
    b = batch["tokens"].shape[0]
    t = max_len or (s + cfg.decode_margin)

    if cfg.family in ("dense", "moe", "vlm"):
        x = lm._embed(params, batch["tokens"], cfg)
        if cfg.family == "vlm":
            x = jnp.concatenate(
                [batch["patches"].astype(cfg.compute_dtype), x], axis=1)
        x = constrain(x, "batch", "seq_sp", None)

        def block_kv(lp, h):
            hh = L.apply_norm(lp, "attn_norm", h, cfg.norm)
            if cfg.use_mla:
                a, kv = L.mla_attention(lp["attn"], hh, cfg, return_kv=True)
            else:
                a, kv = L.gqa_attention(lp["attn"], hh, cfg, return_kv=True)
            h = h + a
            hh = L.apply_norm(lp, "mlp_norm", h, cfg.norm)
            m = (L.moe_mlp(lp["mlp"], hh, cfg) if cfg.family == "moe"
                 else L.swiglu_mlp(lp["mlp"], hh, cfg))
            h = constrain(h + m, "batch", "seq_sp", None)
            return h, kv

        x, kvs = jax.lax.scan(lambda h, lp: block_kv(lp, h), x,
                              params["blocks"])
        hidden = L.apply_norm(params, "final_norm", x, cfg.norm)
        ln = cfg.n_layers
        if cfg.use_mla:
            cache = {"ckv": _pad_time(kvs[0], t),
                     "k_rope": _pad_time(kvs[1], t),
                     "length": jnp.full((ln,), s, jnp.int32)}
        else:
            cache = {"k": _pad_time(kvs[0], t), "v": _pad_time(kvs[1], t),
                     "length": jnp.full((ln,), s, jnp.int32)}
        return cache, lm.logits_last(params, hidden, cfg)

    if cfg.family == "rwkv":
        x = lm._embed(params, batch["tokens"], cfg)
        x = constrain(x, "batch", "seq_sp", None)
        zero = {"wkv": jnp.zeros((b, cfg.n_heads, cfg.hd, cfg.hd), jnp.float32),
                "shift_t": jnp.zeros((b, cfg.d_model), cfg.compute_dtype),
                "shift_c": jnp.zeros((b, cfg.d_model), cfg.compute_dtype)}

        def body(h, lp):
            h2, st = lm.rwkv_block(lp, h, cfg, state=zero)
            return h2, st

        x, states = jax.lax.scan(lambda h, lp: body(h, lp), x, params["blocks"])
        hidden = L.apply_norm(params, "final_norm", x, cfg.norm)
        return states, lm.logits_last(params, hidden, cfg)

    if cfg.family == "hybrid":
        return _hybrid_prefill(params, batch, cfg, b, s, t)

    if cfg.family == "encdec":
        return _encdec_prefill(params, batch, cfg, b, t)

    raise ValueError(cfg.family)


def _hybrid_prefill(params, batch, cfg, b, s, t):
    x = lm._embed(params, batch["tokens"], cfg)
    x = constrain(x, "batch", "seq_sp", None)
    shared_cfg = cfg.replace(family="dense")
    every, n = cfg.shared_attn_every, cfg.n_layers
    zero = {"ssm": jnp.zeros((b, cfg.ssm_heads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((b, cfg.conv_k - 1, cfg.d_inner),
                              cfg.compute_dtype)}
    m_states, a_caches = [], []
    for g0 in range(0, n, every):
        g1 = min(g0 + every, n)
        seg = jax.tree.map(lambda a: a[g0:g1], params["blocks"])

        def body(h, lp):
            h2, st = lm.mamba_block(lp, h, cfg, state=zero)
            return h2, st

        x, sts = jax.lax.scan(lambda h, lp: body(h, lp), x, seg)
        m_states.append(sts)
        if g1 < n:
            hh = L.apply_norm(params["shared_attn"], "attn_norm", x, cfg.norm)
            a, kv = L.gqa_attention(params["shared_attn"]["attn"], hh,
                                    shared_cfg, return_kv=True)
            x = x + a
            hh = L.apply_norm(params["shared_attn"], "mlp_norm", x, cfg.norm)
            x = constrain(x + L.swiglu_mlp(params["shared_attn"]["mlp"], hh,
                                           shared_cfg), "batch", "seq_sp", None)
            a_caches.append(kv)
    hidden = L.apply_norm(params, "final_norm", x, cfg.norm)
    mamba = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *m_states)
    if isinstance(mamba, L.MambaState):      # normalize to the cache schema
        mamba = {"ssm": mamba.ssm, "conv": mamba.conv}
    n_apps = len(a_caches)
    cache = {"mamba": mamba,
             "attn": {"k": _pad_time(jnp.stack([kv[0] for kv in a_caches]), t),
                      "v": _pad_time(jnp.stack([kv[1] for kv in a_caches]), t),
                      "length": jnp.full((n_apps,), s, jnp.int32)}}
    return cache, lm.logits_last(params, hidden, cfg)


def _encdec_prefill(params, batch, cfg, b, t):
    """Encode frames; fill cross-attn K/V; empty self cache."""
    enc = batch["frames"].astype(cfg.compute_dtype)
    enc = constrain(enc, "batch", "seq_sp", None)
    enc = lm._scan_blocks(params["enc_blocks"], enc,
                          lambda lp, h: lm.dense_block(lp, h, cfg, causal=False),
                          remat=False)
    enc = L.apply_norm(params, "final_norm", enc, cfg.norm)

    def cross_kv(lp):
        dt = cfg.compute_dtype
        k = jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wv"].astype(dt))
        return k, v

    _, (ck, cv) = jax.lax.scan(
        lambda _, lp: (None, cross_kv(lp)), None, params["blocks"])
    ln, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    cache = {"self": {"k": jnp.zeros((ln, b, t, kvh, hd), cfg.compute_dtype),
                      "v": jnp.zeros((ln, b, t, kvh, hd), cfg.compute_dtype),
                      "length": jnp.zeros((ln,), jnp.int32)},
             "cross_k": ck, "cross_v": cv}
    bos = jnp.zeros((b, 1), jnp.int32)
    logits, cache = decode_step(params, cache, bos, cfg)
    return cache, logits


# ===========================================================================
# Decode
# ===========================================================================


def decode_step(params, cache, tokens, cfg):
    """One decode step: tokens (B,1) → (logits (B,1,V), new cache)."""
    x = lm._embed(params, tokens, cfg)
    x = constrain(x, "batch", None, None)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, xs):
            lp, c = xs
            pos = c["length"][None, None] + jnp.zeros((1, 1), jnp.int32)
            if cfg.use_mla:
                kv = L.MLACache(c["ckv"], c["k_rope"], c["length"])
                h2, nc = lm.dense_block(lp, h, cfg, cache=kv, positions=pos)
                c2 = {"ckv": nc.ckv, "k_rope": nc.k_rope, "length": nc.length}
            else:
                kv = L.KVCache(c["k"], c["v"], c["length"])
                h2, nc = lm.dense_block(lp, h, cfg, cache=kv, positions=pos)
                c2 = {"k": nc.k, "v": nc.v, "length": nc.length}
            return h2, c2

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))

    elif cfg.family == "rwkv":
        def body(h, xs):
            lp, st = xs
            h2, st2 = lm.rwkv_block(lp, h, cfg, state=st)
            return h2, st2

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))

    elif cfg.family == "hybrid":
        x, new_cache = _hybrid_decode(params, cache, x, cfg)

    elif cfg.family == "encdec":
        def body(h, xs):
            lp, c = xs
            pos = c["length"][None, None] + jnp.zeros((1, 1), jnp.int32)
            kv = L.KVCache(c["k"], c["v"], c["length"])
            hh = L.apply_norm(lp, "attn_norm", h, cfg.norm)
            a, nc = L.gqa_attention(lp["attn"], hh, cfg, cache=kv, positions=pos)
            h = h + a
            hh = L.apply_norm(lp, "cross_norm", h, cfg.norm)
            ca = _cross_decode(lp["cross_attn"], hh, c["ck"], c["cv"], cfg)
            h = h + ca
            hh = L.apply_norm(lp, "mlp_norm", h, cfg.norm)
            h = h + L.swiglu_mlp(lp["mlp"], hh, cfg)
            return h, {"k": nc.k, "v": nc.v, "length": nc.length,
                       "ck": c["ck"], "cv": c["cv"]}

        merged = dict(cache["self"])
        merged["ck"], merged["cv"] = cache["cross_k"], cache["cross_v"]
        x, nc = jax.lax.scan(body, x, (params["blocks"], merged))
        new_cache = {"self": {"k": nc["k"], "v": nc["v"], "length": nc["length"]},
                     "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
    else:
        raise ValueError(cfg.family)

    hidden = L.apply_norm(params, "final_norm", x, cfg.norm)
    return lm.logits_last(params, hidden, cfg), new_cache


def _cross_decode(ap, h, ck, cv, cfg):
    """Single-step cross-attention against precomputed K/V."""
    dt = cfg.compute_dtype
    hn, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", h.astype(dt), ap["wq"].astype(dt))
    krep = L._repeat_kv(ck.astype(dt), hn // kvh)
    vrep = L._repeat_kv(cv.astype(dt), hn // kvh)
    logits = jnp.einsum("bshk,bthk->bhst", q, krep) / np.sqrt(hd)
    p_att = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(dt)
    out = jnp.einsum("bhst,bthk->bshk", p_att, vrep)
    return jnp.einsum("bshk,hkd->bsd", out, ap["wo"].astype(dt))


def _hybrid_decode(params, cache, x, cfg):
    shared_cfg = cfg.replace(family="dense")
    every, n = cfg.shared_attn_every, cfg.n_layers
    new_m, new_a = [], []
    gi = 0
    for g0 in range(0, n, every):
        g1 = min(g0 + every, n)
        seg = jax.tree.map(lambda a: a[g0:g1], params["blocks"])
        seg_cache = jax.tree.map(lambda a: a[g0:g1], cache["mamba"])

        def body(h, xs):
            lp, st = xs
            h2, st2 = lm.mamba_block(lp, h, cfg, state=st)
            return h2, st2

        x, sts = jax.lax.scan(body, x, (seg, seg_cache))
        new_m.append(sts)
        if g1 < n:
            ac = jax.tree.map(lambda a: a[gi], cache["attn"])
            kv = L.KVCache(ac["k"], ac["v"], ac["length"])
            pos = ac["length"][None, None] + jnp.zeros((1, 1), jnp.int32)
            hh = L.apply_norm(params["shared_attn"], "attn_norm", x, cfg.norm)
            a, nc = L.gqa_attention(params["shared_attn"]["attn"], hh,
                                    shared_cfg, cache=kv, positions=pos)
            x = x + a
            hh = L.apply_norm(params["shared_attn"], "mlp_norm", x, cfg.norm)
            x = x + L.swiglu_mlp(params["shared_attn"]["mlp"], hh, shared_cfg)
            new_a.append({"k": nc.k, "v": nc.v, "length": nc.length})
            gi += 1
    mamba = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_m)
    attn = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *new_a)
    return x, {"mamba": mamba, "attn": attn}
