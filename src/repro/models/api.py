"""Public model API: one ``Model`` object per architecture config.

Wraps the family assemblies with a uniform surface used by the trainer,
server, dry-run and tests:

    model = Model(get_config("qwen2-72b"))
    params = model.init_params(key)          # reduced configs only
    loss   = model.loss(params, microbatch)
    cache, logits = model.prefill(params, batch)
    logits, cache = model.decode_step(params, cache, tokens)
    model.input_specs(SHAPES["train_4k"])    # ShapeDtypeStructs for dry-run
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import lm, serve
from repro.models import spec as S
from repro.parallel import sharding


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- params

    @functools.cached_property
    def specs(self):
        specs = lm.param_specs(self.cfg)
        if self.cfg.param_dtype != jnp.float32:
            # pure-low-precision params (no f32 master): halves resident
            # bytes AND the FSDP gather wire.  Norm scales / biases / tiny
            # vectors stay f32 (cheap, numerically load-bearing).
            specs = S.map_axes(
                specs, lambda s: dataclasses.replace(
                    s, dtype=self.cfg.param_dtype) if len(s.shape) >= 2 else s)
        return specs

    def abstract_params(self):
        return S.abstract(self.specs)

    def init_params(self, key: jax.Array):
        return S.initialize(self.specs, key)

    def param_partition_specs(self):
        return sharding.tree_partition_specs(self.specs)

    def param_count(self) -> int:
        return S.param_count(self.specs)

    def active_param_count(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6·N_active·D)."""
        cfg = self.cfg
        total = self.param_count()
        if cfg.family != "moe":
            return total
        e, k = cfg.n_experts, cfg.moe_top_k
        expert_p = 3 * cfg.d_model * cfg.d_ff * e * cfg.n_layers
        active_expert_p = expert_p * k // e
        return total - expert_p + active_expert_p

    # ------------------------------------------------------------- compute

    def loss(self, params, batch):
        return lm.loss_fn(params, batch, self.cfg)

    def prefill(self, params, batch, max_len: Optional[int] = None):
        return serve.prefill(params, batch, self.cfg, max_len=max_len)

    def decode_step(self, params, cache, tokens):
        return serve.decode_step(params, cache, tokens, self.cfg)

    # ------------------------------------------------------------- specs

    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of a shape."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        sds = jax.ShapeDtypeStruct
        i32 = jnp.int32
        if shape.kind == "train":
            out = self._train_batch_struct(b, s)
        elif shape.kind == "prefill":
            out = self._prompt_struct(b, s)
        elif shape.kind == "decode":
            out = {"tokens": sds((b, 1), i32),
                   "cache": serve.cache_struct(cfg, b, s + cfg.decode_margin)}
        else:
            raise ValueError(shape.kind)
        return out

    def _train_batch_struct(self, b, s):
        cfg = self.cfg
        sds, i32 = jax.ShapeDtypeStruct, jnp.int32
        s_text = s - cfg.n_patches if cfg.family == "vlm" else s
        out = {"tokens": sds((b, s_text), i32),
               "targets": sds((b, s_text), i32),
               "mask": sds((b, s_text), jnp.float32)}
        if cfg.family == "vlm":
            out["patches"] = sds((b, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            out["frames"] = sds((b, s_text, cfg.d_model), jnp.float32)
        return out

    def _prompt_struct(self, b, s):
        cfg = self.cfg
        sds, i32 = jax.ShapeDtypeStruct, jnp.int32
        s_text = s - cfg.n_patches if cfg.family == "vlm" else s
        out = {"tokens": sds((b, s_text), i32)}
        if cfg.family == "vlm":
            out["patches"] = sds((b, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            out["frames"] = sds((b, s_text, cfg.d_model), jnp.float32)
        return out

    def batch_axes(self, shape: ShapeSpec) -> Dict[str, Any]:
        """Logical axes per input (mirrors input_specs)."""
        cfg = self.cfg
        tok = ("batch", "seq")
        if shape.kind in ("train", "prefill"):
            out = {k: tok for k in ("tokens", "targets", "mask")}
            if shape.kind == "prefill":
                out = {"tokens": tok}
            if cfg.family == "vlm":
                out["patches"] = ("batch", "seq", None)
            if cfg.family == "encdec":
                out["frames"] = ("batch", "seq", None)
            return out
        return {"tokens": ("batch", None), "cache": serve.cache_axes(cfg)}

    # ------------------------------------------------------------- data gen

    def make_batch(self, shape_kind: str, b: int, s: int, seed: int = 0):
        """Materialize a random batch (smoke tests / examples)."""
        cfg = self.cfg
        rng = np.random.default_rng(seed)
        s_text = s - cfg.n_patches if cfg.family == "vlm" else s
        toks = rng.integers(0, cfg.vocab, size=(b, s_text), dtype=np.int32)
        out = {"tokens": jnp.asarray(toks)}
        if shape_kind == "train":
            tgt = np.roll(toks, -1, axis=1)
            out["targets"] = jnp.asarray(tgt)
            out["mask"] = jnp.ones((b, s_text), jnp.float32)
        if cfg.family == "vlm":
            out["patches"] = jnp.asarray(
                rng.standard_normal((b, cfg.n_patches, cfg.d_model)), jnp.float32)
        if cfg.family == "encdec":
            out["frames"] = jnp.asarray(
                rng.standard_normal((b, s_text, cfg.d_model)), jnp.float32)
        return out


def build_model(name: str, reduced: bool = False) -> Model:
    from repro.configs.base import get_config
    return Model(get_config(name, reduced=reduced))
