"""Batched serving engine.

Static-batch engine over the model's ``prefill``/``decode_step``:
requests are grouped into fixed-size batches (padding short prompts),
prefilled once, then decoded step-by-step with greedy or temperature
sampling.  Weight distribution to serving hosts uses the CDMT pull path
(examples/serve_weights.py) — a new model version moves only changed chunks.

This is deliberately the *simple, correct* engine: the dry-run shapes
(decode_32k, long_500k) exercise the sharded decode step itself via
launch/dryrun.py; this engine exists so examples and tests can run real
token loops on CPU.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0         # 0 = greedy
    # filled by the engine
    output: Optional[np.ndarray] = None
    latency_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_size: int = 8
    max_len: int = 512
    seed: int = 0


class ServingEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=cfg.max_len))

    def _pad_prompts(self, reqs: List[Request]) -> Tuple[np.ndarray, np.ndarray]:
        maxlen = max(len(r.prompt) for r in reqs)
        b = len(reqs)
        toks = np.zeros((b, maxlen), np.int32)
        lens = np.zeros((b,), np.int32)
        for i, r in enumerate(reqs):
            toks[i, maxlen - len(r.prompt):] = r.prompt    # left-pad
            lens[i] = len(r.prompt)
        return toks, lens

    def serve_batch(self, reqs: List[Request]) -> List[Request]:
        """Prefill + decode one batch of requests to completion."""
        assert len(reqs) <= self.cfg.batch_size
        t0 = time.time()
        cfg_m = self.model.cfg
        toks, _ = self._pad_prompts(reqs)
        batch = {"tokens": jnp.asarray(toks)}
        if cfg_m.family == "vlm":
            batch["patches"] = jnp.zeros(
                (len(reqs), cfg_m.n_patches, cfg_m.d_model), jnp.float32)
        if cfg_m.family == "encdec":
            batch["frames"] = jnp.zeros(
                (len(reqs), toks.shape[1], cfg_m.d_model), jnp.float32)
        cache, logits = self._prefill(self.params, batch)

        max_new = max(r.max_new_tokens for r in reqs)
        outs = np.zeros((len(reqs), max_new), np.int32)
        key = jax.random.PRNGKey(self.cfg.seed)
        for t in range(max_new):
            if reqs[0].temperature > 0:
                key, sk = jax.random.split(key)
                nxt = jax.random.categorical(
                    sk, jnp.asarray(logits[:, -1]) / reqs[0].temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits[:, -1], axis=-1)
            nxt = nxt.astype(jnp.int32)[:, None]
            outs[:, t] = np.asarray(nxt)[:, 0]
            logits, cache = self._decode(self.params, cache, nxt)
        dt = time.time() - t0
        for i, r in enumerate(reqs):
            r.output = outs[i, :r.max_new_tokens]
            r.latency_s = dt
        return reqs

    def serve(self, reqs: List[Request]) -> Dict[str, float]:
        """Serve all requests in batches; returns throughput metrics."""
        t0 = time.time()
        done: List[Request] = []
        for i in range(0, len(reqs), self.cfg.batch_size):
            done.extend(self.serve_batch(reqs[i:i + self.cfg.batch_size]))
        wall = time.time() - t0
        new_tokens = sum(r.max_new_tokens for r in done)
        return {"requests": len(done), "wall_s": wall,
                "tokens_per_s": new_tokens / wall if wall else 0.0}
