"""Serving runtime: batched prefill/decode over the model serve paths."""
from repro.serving.engine import ServeConfig, ServingEngine, Request
