"""SeamlessM4T-large-v2 [arXiv:2308.11596]: enc-dec, 24L(+24L dec), d=1024,
16H, ff 8192, vocab 256206.  Modality frontend is a STUB: input_specs()
provides precomputed frame embeddings (brief/DESIGN §6)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="seamless-m4t-large-v2", family="encdec",
        n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=256206,
    ),
    reduced=ModelConfig(
        name="seamless-m4t-large-v2", family="encdec",
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, loss_chunk=32, ssm_segment=16,
    ),
)
