"""Model/shape configuration system.

One ``ModelConfig`` per assigned architecture (exact public-literature dims)
plus a ``reduced()`` shrink for CPU smoke tests.  Shapes are the assigned
input-shape set; ``applicable_shapes`` enforces the brief's skip rules
(long_500k only for sub-quadratic archs).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | rwkv | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 → d_model // n_heads
    # attention options
    qkv_bias: bool = False
    norm: str = "rmsnorm"             # rmsnorm | nonparam_ln
    mlp_kind: str = "swiglu"          # swiglu | gelu (GPT-BigCode 2-matrix)
    use_rope: bool = True
    rope_theta: float = 10000.0
    attention_impl: str = "blockwise"  # blockwise | naive
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    # MLA (DeepSeek-V2)
    use_mla: bool = False
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 4096        # tokens per GShard dispatch group
    # Expert-FFN tensor parallelism over the DATA axis (shard_map explicit
    # collectives).  ANALYZED AND REJECTED for high-expert-count MoE
    # (EXPERIMENTS §Perf): with tokens data-sharded, the required
    # all-to-all + activation reductions move MORE bytes per layer than the
    # FSDP weight gather they replace (tokens-per-expert ≪ weights-per-
    # expert at 160 experts).  Kept as an option for low-expert configs.
    moe_ffn_tp: bool = False
    # SSM / RWKV
    d_inner: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_state: int = 64
    conv_k: int = 4
    ssm_segment: int = 256
    rwkv_lora: int = 64
    # WKV execution: "serial" (token scan, paper-faithful recurrence) or
    # "chunked" (segmented matmul formulation — the TPU adaptation; §Perf)
    wkv_impl: str = "serial"
    wkv_chunk: int = 32
    # Mamba2/SSD execution: "serial" token scan or "chunked" SSD blocks
    ssm_impl: str = "serial"
    ssd_chunk: int = 128
    # hybrid
    shared_attn_every: int = 6
    # enc-dec
    n_enc_layers: int = 0
    # vlm
    n_patches: int = 0
    # numerics
    compute_dtype: object = jnp.bfloat16
    param_dtype: object = jnp.float32
    opt_state_dtype: object = jnp.float32
    grad_accum_dtype: object = jnp.float32
    loss_chunk: int = 512
    # training
    remat: bool = True
    train_n_micro: int = 4            # grad-accum microbatches for train_4k
    # serving
    decode_margin: int = 128          # extra cache slots beyond seq_len
                                      # (128 keeps cache length divisible by
                                      # the mesh axes for cache_seq sharding)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Embedding-table vocab padded to a multiple of 128 so the vocab
        axis shards over any mesh axis (92553 → 92672 etc.).  Logits over
        padded columns are real (trained) params that no target indexes —
        the standard MaxText-style treatment."""
        return ((self.vocab + 127) // 128) * 128

    def applicable_shapes(self) -> List[str]:
        out = ["train_4k", "prefill_32k", "decode_32k"]
        if self.family in ("rwkv", "hybrid"):
            out.append("long_500k")   # sub-quadratic archs only (DESIGN §6)
        return out

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# Registry -------------------------------------------------------------------

_REGISTRY: Dict[str, "ModelConfig"] = {}
_REDUCED: Dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig, reduced: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    _REDUCED[cfg.name] = reduced
    return cfg


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    _ensure_loaded()
    return (_REDUCED if reduced else _REGISTRY)[name]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY.keys())


def _ensure_loaded():
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        olmo_1b, granite_20b, qwen2_72b, internlm2_20b, seamless_m4t_large_v2,
        internvl2_2b, deepseek_v2_236b, olmoe_1b_7b, rwkv6_3b, zamba2_1p2b)
