"""OLMo-1B [arXiv:2402.00838]: 16L, d=2048, 16H (MHA), ff 8192, vocab 50304.
Distinctive: non-parametric LayerNorm."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="olmo-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=50304, norm="nonparam_ln",
    ),
    reduced=ModelConfig(
        name="olmo-1b", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, norm="nonparam_ln",
        loss_chunk=32, ssm_segment=16,
    ),
)
