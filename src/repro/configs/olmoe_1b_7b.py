"""OLMoE-1B-7B [arXiv:2409.02060]: 16L, d=2048, 16H, MoE 64 experts top-8,
expert ff 1024, vocab 50304."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab=50304,
        n_experts=64, moe_top_k=8, n_shared_experts=0,
    ),
    reduced=ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, vocab=512, n_experts=8, moe_top_k=2, n_shared_experts=0,
        loss_chunk=32, ssm_segment=16,
    ),
)
