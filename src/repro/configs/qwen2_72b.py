"""Qwen2-72B [arXiv:2407.10671]: 80L, d=8192, 64H GQA kv=8, ff 29568,
vocab 152064.  Distinctive: QKV bias, rope_theta 1e6."""
import jax.numpy as jnp
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-72b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, qkv_bias=True, rope_theta=1e6,
        opt_state_dtype=jnp.bfloat16,   # 72B: keep optimizer in HBM budget
    ),
    reduced=ModelConfig(
        name="qwen2-72b", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, qkv_bias=True, loss_chunk=32, ssm_segment=16,
    ),
)
