"""Granite-20B-Code [arXiv:2405.04324]: 52L, d=6144, 48H MQA (kv=1),
ff 24576, vocab 49152 — llama-style architecture for code."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="granite-20b", family="dense",
        n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab=49152,
        mlp_kind="gelu",   # GPT-BigCode 2-matrix MLP (matches the 20B count)
    ),
    reduced=ModelConfig(
        name="granite-20b", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab=512, mlp_kind="gelu", loss_chunk=32, ssm_segment=16,
    ),
)
