"""InternVL2-2B [arXiv:2404.16821]: InternLM2-1.8B backbone, 24L, d=2048,
16H GQA kv=8, ff 8192, vocab 92553.  InternViT frontend is a STUB:
input_specs() provides precomputed patch embeddings."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="internvl2-2b", family="vlm",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=8192, vocab=92553, n_patches=256,
    ),
    reduced=ModelConfig(
        name="internvl2-2b", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, n_patches=8, loss_chunk=32, ssm_segment=16,
    ),
)
