"""RWKV6-3B "Finch" [arXiv:2404.05892]: 32L, d=2560, attn-free, channel-mix
ff 8960, vocab 65536.  Data-dependent decay; heads of size 64."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="rwkv6-3b", family="rwkv",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
        d_ff=8960, vocab=65536, use_rope=False, rwkv_lora=64,
        # chunked WKV (exact reformulation, §Perf Cell A): 8.7× better
        # memory roofline than the token-serial recurrence
        wkv_impl="chunked",
    ),
    reduced=ModelConfig(
        name="rwkv6-3b", family="rwkv",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512, use_rope=False, rwkv_lora=16,
        loss_chunk=32, ssm_segment=16,
    ),
)
