"""Zamba2-1.2B [arXiv:2411.15242]: 38 Mamba2 layers, d=2048, ssm_state=64,
plus a SHARED attention block (32H, ff 8192) applied every 6 layers."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32000,
        d_inner=4096, ssm_heads=64, ssm_head_dim=64, ssm_state=64,
        shared_attn_every=6,
        # chunked SSD (exact Mamba2 block decomposition, §Perf): replaces
        # the token-serial scan's per-token state HBM round-trips
        ssm_impl="chunked",
    ),
    reduced=ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512,
        d_inner=128, ssm_heads=8, ssm_head_dim=16, ssm_state=16,
        shared_attn_every=2, loss_chunk=32, ssm_segment=16,
    ),
)
