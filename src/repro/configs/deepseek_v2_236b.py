"""DeepSeek-V2-236B [arXiv:2405.04434]: 60L, d=5120, 128H MLA
(kv_lora=512, rope 64), MoE 2 shared + 160 routed top-6, expert ff 1536,
vocab 102400."""
import jax.numpy as jnp
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        head_dim=128, d_ff=1536, vocab=102400,
        use_mla=True, q_lora=1536, kv_lora=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        n_experts=160, moe_top_k=6, n_shared_experts=2,
        opt_state_dtype=jnp.bfloat16,   # 236B: keep optimizer in HBM budget
        grad_accum_dtype=jnp.bfloat16,  # halve the accumulation buffer too
        param_dtype=jnp.bfloat16,       # pure-bf16 2-D-sharded params (§Perf It.7)
        train_n_micro=8,                # §Perf It.5: best memory/perf point
    ),
    reduced=ModelConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=32, vocab=512,
        use_mla=True, q_lora=32, kv_lora=16, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16, n_experts=8, moe_top_k=2, n_shared_experts=1,
        loss_chunk=32, ssm_segment=16,
    ),
)
