"""InternLM2-20B [arXiv:2403.17297]: 48L, d=6144, 48H GQA kv=8, ff 16384,
vocab 92544."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="internlm2-20b", family="dense",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab=92544,
    ),
    reduced=ModelConfig(
        name="internlm2-20b", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, loss_chunk=32, ssm_segment=16,
    ),
)
