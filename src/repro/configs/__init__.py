"""Architecture configs (one per assigned arch) + shape registry."""
from repro.configs.base import (SHAPES, ModelConfig, ShapeSpec, get_config,
                                list_archs)
