"""Logical-axis sharding: rules mapping model axis names → mesh axes.

MaxText-style: model code annotates parameters and activations with *logical*
axis names; a rule table (swappable per experiment — this is the main
hillclimbing knob) resolves them to mesh axes.  With no active mesh (CPU
smoke tests) every constraint is the identity.

Default layout (single pod, mesh ``(data=16, model=16)``):
  * weights: ``embed → data`` (FSDP/ZeRO-3 dimension) × ``heads/mlp/vocab/
    experts → model`` (tensor/expert dimension) ⇒ params+opt state sharded
    over all 256 chips.
  * activations: ``batch → (pod, data)``; residual-stream ``seq → model``
    (sequence parallelism, so remat-saved activations are 1/16 per chip).
Multi-pod default keeps ``pod`` on batch (cross-pod DP); pipeline mode
reassigns it (see parallel/pipeline.py).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, Tuple[str, ...], None]

# Logical axis -> mesh axis (or tuple of mesh axes) or None (replicated).
Rules = Dict[str, MeshAxes]

# fmt: off
DEFAULT_RULES: Rules = {
    # parameter axes
    "embed":     "data",     # FSDP shard dim of weight matrices
    "embed_out": None,       # second embed dim where both appear (w2)
    "vocab":     "model",
    "heads":     "model",
    "kv_heads":  "model",
    "head_dim":  None,
    "mlp":       "model",
    "experts":   "model",    # expert parallelism
    "expert_mlp": None,
    "expert_ffn": "data",    # w2 contraction dim (row-parallel over data)
    "layers":    None,
    "state":     None,
    "conv":      None,
    "lora":      "data",     # MLA/RWKV low-rank dims: FSDP-shard (dedup'd
                             # to None when "data" already used by "embed")
    "null":      None,
    # activation axes
    "batch":     ("pod", "data"),
    "seq":       None,
    "seq_sp":    "model",    # residual stream between blocks (SP)
    "kv_seq":    None,
    "act_embed": None,
    "act_heads": "model",
    "act_kv":    "model",
    "act_mlp":   "model",
    "act_experts": "model",
    "cache_batch": ("pod", "data"),
    "cache_seq": None,
    "cache_heads": "model",
}
# fmt: on


@dataclasses.dataclass
class ShardingContext:
    mesh: Optional[Mesh] = None
    rules: Rules = dataclasses.field(default_factory=lambda: dict(DEFAULT_RULES))
    exclude: frozenset = frozenset()   # mesh axes constraints must not use
                                       # (e.g. the manual axis inside a
                                       # partially-manualized shard_map)


_ctx = threading.local()


def _get() -> ShardingContext:
    if not hasattr(_ctx, "v"):
        _ctx.v = ShardingContext()
    return _ctx.v


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Rules] = None,
             exclude: frozenset = frozenset()):
    """Activate mesh+rules for model code executed inside (incl. tracing)."""
    prev = _get()
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    _ctx.v = ShardingContext(mesh=mesh, rules=merged, exclude=frozenset(exclude))
    try:
        yield _ctx.v
    finally:
        _ctx.v = prev


@contextlib.contextmanager
def exclude_axes(*axes: str):
    """Within a partially-manualized shard_map body, constraints must not
    reference the manual axes — drop them from rule resolution."""
    prev = _get()
    _ctx.v = ShardingContext(mesh=prev.mesh, rules=dict(prev.rules),
                             exclude=prev.exclude | frozenset(axes))
    try:
        yield _ctx.v
    finally:
        _ctx.v = prev


def active_mesh() -> Optional[Mesh]:
    return _get().mesh


def _resolve_axis(name: Optional[str], rules: Rules, mesh: Mesh,
                  exclude: frozenset = frozenset()) -> MeshAxes:
    if name is None:
        return None
    axes = rules.get(name)
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.axis_names and axes not in exclude \
            else None
    present = tuple(a for a in axes
                    if a in mesh.axis_names and a not in exclude)
    return present if present else None


def logical_to_pspec(axes: Tuple[Optional[str], ...]) -> P:
    """Resolve logical axes to a PartitionSpec under the active context."""
    ctx = _get()
    if ctx.mesh is None:
        return P()
    resolved = []
    used = set()
    for name in axes:
        r = _resolve_axis(name, ctx.rules, ctx.mesh, ctx.exclude)
        # a mesh axis may appear only once in a PartitionSpec
        if isinstance(r, tuple):
            r = tuple(a for a in r if a not in used) or None
        if isinstance(r, str) and r in used:
            r = None
        if r is not None:
            used.update(r if isinstance(r, tuple) else (r,))
        resolved.append(r)
    return P(*resolved)


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op without mesh)."""
    ctx = _get()
    if ctx.mesh is None:
        return x
    spec = logical_to_pspec(tuple(axes))
    mesh = ctx.mesh
    # inside a (partially-manual) shard_map the constraint must carry the
    # ambient abstract mesh — its axis types differ from the concrete mesh
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names == mesh.axis_names and \
                any("Manual" in str(t) for t in am.axis_types):
            mesh = am
    except Exception:      # noqa: BLE001 — older jax: no abstract mesh API
        pass
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_axes(x: jax.Array, axes) -> jax.Array:
    return constrain(x, *axes)


def named_sharding(axes: Tuple[Optional[str], ...]) -> Optional[NamedSharding]:
    ctx = _get()
    if ctx.mesh is None:
        return None
    return NamedSharding(ctx.mesh, logical_to_pspec(axes))


def tree_partition_specs(spec_tree):
    """ParamSpec tree -> PartitionSpec tree under the active context."""
    from repro.models import spec as pspec_mod
    return pspec_mod.map_axes(
        spec_tree, lambda s: logical_to_pspec(s.axes))


def tree_named_shardings(spec_tree):
    ctx = _get()
    assert ctx.mesh is not None, "tree_named_shardings requires an active mesh"
    from repro.models import spec as pspec_mod
    return pspec_mod.map_axes(
        spec_tree,
        lambda s: NamedSharding(ctx.mesh, logical_to_pspec(s.axes)))
