"""GPipe-style pipeline parallelism over a mesh axis via shard_map.

The multi-pod mesh declares ``pod`` outermost; by default it extends data
parallelism, but for models whose layer stack exceeds one pod's HBM the
launcher can instead assign ``pod`` as the PIPELINE axis: each pod holds a
contiguous stage of layers and microbatches stream through with
``jax.lax.ppermute`` boundary handoffs.

Schedule: GPipe (fill–steady–drain).  For S stages and M microbatches the
bubble fraction is (S-1)/(M+S-1) — the launcher picks M ≥ 4·S.  Stage
weights live only on their stage's devices (enforced by shard_map's
in_specs), so HBM per pod is 1/S of the stack.

This module is deliberately self-contained (plain functions over a stacked
layer pytree) so it composes with ANY of the 10 block functions: the stage
body is the same scanned block used by the non-pipelined path.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def stage_layers(params_stacked, n_stages: int):
    """Reshape a (L, ...) stacked layer tree to (S, L/S, ...)."""
    def r(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape((n_stages, l // n_stages) + x.shape[1:])
    return jax.tree.map(r, params_stacked)


def pipeline_forward(stage_params, x_microbatches, stage_ids,
                     block_fn: Callable, *, axis: str = "pod",
                     remat: bool = True):
    """Run microbatches through pipeline stages inside shard_map.

    ``stage_params``: (S, L/S, ...) tree sharded so each device along
    ``axis`` holds its own stage (leading dim 1 per device).
    ``x_microbatches``: (M, mb, S_len, d) activations, replicated along
    ``axis``.  ``stage_ids``: the (S,) iota sharded P(axis) — its (1,)
    per-device slice is this device's stage index (compat.axis_index_input;
    ``jax.lax.axis_index`` lowers to a PartitionId HLO that old-jax SPMD
    partitioning rejects inside partial-auto shard_map).  Returns
    (M, mb, S_len, d) outputs (valid on the LAST stage; callers read them
    there).
    """
    from repro.parallel.compat import (LEGACY_PARTIAL_AUTO, axis_size,
                                       shift_up, unrolled_scan)
    n_stages = axis_size(axis)
    stage_id = stage_ids[0]
    m = x_microbatches.shape[0]

    # local stage params: shard_map gives us the (1, L/S, ...) slice
    local = jax.tree.map(lambda a: a[0], stage_params)

    f = jax.checkpoint(block_fn) if remat else block_fn

    def run_stage(h):
        def body(carry, lp):
            out, _ = f(lp, carry)
            return out, None
        out, _ = unrolled_scan(body, h, local)
        return out

    n_ticks = m + n_stages - 1
    zero = jnp.zeros_like(x_microbatches[0])

    if not LEGACY_PARTIAL_AUTO:
        # indexed schedule: O(one microbatch) work per tick — stage 0 reads
        # x[t], the last stage writes outputs[t-(S-1)] in place
        outputs0 = jnp.zeros_like(x_microbatches)

        def tick(state, t):
            inflight, outputs = state
            mb_idx = jnp.clip(t, 0, m - 1)
            inject = jax.lax.select(t < m, x_microbatches[mb_idx], zero)
            h_in = jnp.where(stage_id == 0, inject, inflight)
            h_out = run_stage(h_in)
            # pass to the next stage (ring permute; last→first slot unused)
            handoff = shift_up(h_out, axis, stage_id)
            emit_idx = t - (n_stages - 1)
            valid = jnp.logical_and(stage_id == n_stages - 1, emit_idx >= 0)
            outputs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h_out, jnp.clip(emit_idx, 0, m - 1), 0),
                lambda o: o, outputs)
            return (handoff, outputs), None

        (_, outputs), _ = jax.lax.scan(tick, (zero, outputs0),
                                       jnp.arange(n_ticks))
    else:
        # FIFO schedule for old jax, whose partial-auto partitioner crashes
        # on every loop-index-dependent pattern above (x[t]-style gathers,
        # DynamicUpdateSlice/one-hot writes, even hoisted device-varying
        # booleans closed over by a scan body): stage 0 pops its next
        # microbatch off the front of a shifting feed queue (zeros after the
        # first m ticks = drain phase) and the last stage pushes h_out onto
        # the back of a length-m emit queue, so the body uses only static
        # slices/concats.  The last stage emits microbatch t-(S-1) at tick
        # t, so after m + S - 1 ticks the queue holds microbatches 0..m-1.
        # Costs O(m) copies per tick — acceptable on the compat path only.
        def tick(state, _):
            inflight, feed, outputs = state
            is_first = stage_id == 0        # must stay INSIDE the loop body
            is_last = stage_id == n_stages - 1
            inject = feed[0]
            feed = jnp.concatenate([feed[1:], feed[:1] * 0])
            h_in = jnp.where(is_first, inject, inflight)
            h_out = run_stage(h_in)
            # ring shift via compat.shift_up's psum-gather emulation
            handoff = shift_up(h_out, axis, stage_id)
            emit = jnp.where(is_last, h_out, zero)
            outputs = jnp.concatenate([outputs[1:], emit[None]])
            return (handoff, feed, outputs), None

        state0 = (zero, x_microbatches, jnp.zeros_like(x_microbatches))
        (_, _, outputs), _ = unrolled_scan(tick, state0, None,
                                           length=n_ticks)
    # only the last stage emitted (zeros elsewhere): psum replicates its
    # outputs across the pipeline axis so out_specs=P() is truly replicated
    return jax.lax.psum(outputs, axis)


def make_pipelined_fwd(mesh: Mesh, block_fn: Callable, n_stages: int,
                       *, axis: str = "pod", remat: bool = True):
    """shard_map-wrapped pipeline forward.

    Returns ``fwd(stage_params, x_microbatches) -> outputs`` where
    stage_params' leading dim is sharded over ``axis`` and activations are
    replicated over ``axis`` (their batch/model sharding is inherited from
    inner constraints).
    """
    fwd = functools.partial(pipeline_forward, block_fn=block_fn, axis=axis,
                            remat=remat)
    in_specs = (P(axis), P(), P(axis))
    out_specs = P()
    # manualize ONLY the pipeline axis (axis_names): the stage body keeps
    # the other mesh axes in auto (GSPMD) mode, so Megatron TP / sequence
    # sharding inside the blocks composes with the pipeline (TP-inside-PP).
    from repro.parallel.compat import axis_index_input, shard_map
    mapped = shard_map(fwd, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False,
                       axis_names=frozenset({axis}))

    def run(stage_params, x_microbatches):
        return mapped(stage_params, x_microbatches,
                      axis_index_input(n_stages))
    return run


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipelined_loss_fn(cfg, mesh, *, n_stages: int, n_micro: int,
                      axis: str = "pod"):
    """Dense-family LM loss with the layer stack pipelined over ``axis``.

    Params use the standard tree EXCEPT ``blocks`` leaves carry a leading
    (n_stages, L/n_stages, ...) layout sharded P(axis) — each pod holds
    only its stage (1/S of the stack in HBM).  Embedding/head run on every
    stage (they are small and the last stage needs them); microbatches
    stream through GPipe-style.

    Returns ``loss_fn(params, batch)`` suitable for jit/grad — AD flows
    through the shard_map/ppermute schedule.
    """
    from repro.models import lm
    from repro.parallel import sharding as sh

    def block_fn(lp, h):
        # pod is manual inside the pipeline shard_map: constraints in the
        # block must not reference it (batch/cache rules include pod)
        with sh.exclude_axes(axis):
            return lm.dense_block(lp, h, cfg)

    fwd = make_pipelined_fwd(mesh, block_fn, n_stages, axis=axis)

    def loss_fn(params, batch):
        from repro.models import layers as L
        tokens, targets, mask = (batch["tokens"], batch["targets"],
                                 batch["mask"])
        x = lm._embed(params, tokens, cfg)                # (B,S,d)
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        xm = x.reshape((n_micro, b // n_micro) + x.shape[1:])
        outs = fwd(params["blocks"], xm)                  # (M, mb, S, d)
        hidden = outs.reshape((b,) + outs.shape[2:])
        hidden = L.apply_norm(params, "final_norm", hidden, cfg.norm)
        return lm.lm_loss_from_hidden(params, hidden, targets, mask, cfg)

    return loss_fn


def pipeline_param_specs(model, n_stages: int):
    """Abstract params with blocks staged: (S, L/S, ...) leading dims."""
    import jax
    params = model.abstract_params()
    def restage(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return jax.ShapeDtypeStruct(
            (n_stages, l // n_stages) + a.shape[1:], a.dtype)
    params["blocks"] = jax.tree.map(restage, params["blocks"])
    return params
