"""jax API compatibility shims.

The framework targets the modern ``jax.shard_map`` API (``check_vma``,
``axis_names``); older jax releases ship it as
``jax.experimental.shard_map.shard_map`` with ``check_rep`` and the
complementary ``auto`` set.  This adapter lets every call site use the new
signature unconditionally.
"""

from __future__ import annotations

from typing import FrozenSet, Optional

import jax


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None,
              axis_names: Optional[FrozenSet[str]] = None):
    """``jax.shard_map`` on new jax; experimental fallback on old jax."""
    if hasattr(jax, "shard_map"):
        kwargs = {}
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    kwargs = {}
    if check_vma is not None:        # omit to keep each version's default
        kwargs["check_rep"] = check_vma
    if axis_names is not None:
        # old API: `auto` is the complement — axes left in GSPMD auto mode
        kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def axis_size(axis_name: str):
    """``jax.lax.axis_size`` on new jax; psum-of-ones fallback on old jax
    (same value, resolved at trace time inside shard_map/pmap bodies)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


# ---------------------------------------------------------------------------
# Partial-auto collectives.
#
# ``LEGACY_PARTIAL_AUTO``: True on old jax (no ``jax.shard_map``), whose XLA
# SPMD partitioner is the fragile one described below — callers use it to
# pick emulation paths; on modern jax everything takes the native route.
#
# Old-jax *partial-auto* shard_map (manual over a subset of mesh axes, the
# rest left to GSPMD) is where the XLA SPMD partitioner falls over:
#
#   * ``jax.lax.axis_index`` lowers to a ``partition-id`` HLO →
#     "PartitionId instruction is not supported for SPMD partitioning";
#   * ``ppermute`` / ``all_gather`` in the manual subgroup hard-crash the
#     partitioner (``Check failed: sharding.IsManualSubgroup()``).
#
# Only ``psum`` partitions reliably there.  The two helpers below give the
# pipeline supported equivalents:
#
#   * the axis index is *data-derived*: pass ``axis_index_input(n)`` as an
#     extra shard_map operand with ``in_specs=P(axis)`` — each device's
#     (1,)-slice of the iota IS its index, no collective involved;
#   * the ring handoff (``ppermute`` shift-by-one) is emulated with a
#     psum-of-one-hot gather when real ppermute would crash.

LEGACY_PARTIAL_AUTO = not hasattr(jax, "shard_map")


def unrolled_scan(body, init, xs, length=None):
    """``jax.lax.scan`` on new jax; a fully Python-unrolled loop on old jax.

    The old partitioner cannot even transpose a *plain* ``lax.scan`` inside
    a partial-auto region (the backward while-loop trips the same manual-
    subgroup check), so on that path the loop is unrolled at trace time —
    fine for pipeline schedules, whose trip counts (ticks, layers-per-stage)
    are small and static.  Only the scan features the pipeline uses are
    supported: ``xs`` a stacked tree or ``None``, per-step outputs ignored.
    """
    if not LEGACY_PARTIAL_AUTO:
        return jax.lax.scan(body, init, xs, length=length)
    if xs is None:
        n = length
    else:
        n = jax.tree.leaves(xs)[0].shape[0]
    carry = init
    for i in range(n):
        x_i = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, _ = body(carry, x_i)
    return carry, None


def axis_index_input(n: int):
    """Host-side iota to pass through shard_map with ``in_specs=P(axis)``;
    inside the body, ``operand[0]`` is the device's index along ``axis``.
    The data-derived equivalent of ``jax.lax.axis_index`` that works in
    partial-auto regions on every jax version."""
    import jax.numpy as jnp
    return jnp.arange(n, dtype=jnp.int32)


def shift_up(x, axis_name: str, axis_idx):
    """``ppermute(x, axis, [(i, i+1)])`` — device ``i`` receives ``x`` from
    device ``i-1``; device 0 receives zeros.

    New jax: real ``ppermute``.  Old jax (partial-auto): emulated as
    ``psum`` of one-hot-masked contributions — every device receives the
    full (n, *x.shape) gather and selects slot ``i-1`` by a one-hot
    contraction — because psum is the only collective the old SPMD
    partitioner accepts in a partial-auto region, and the one-hot
    multiply-sum (unlike a dynamic index, whose *gradient* is the
    DynamicUpdateSlice that crashes that partitioner) stays elementwise in
    both directions of AD.  Device 0's mask (index -1) is all-zero, which
    yields the ppermute zero-fill for free.  Costs n× the ppermute
    bandwidth; acceptable as a compatibility path (the modern API takes the
    cheap route).
    """
    import jax.numpy as jnp
    n = axis_size(axis_name)
    if not LEGACY_PARTIAL_AUTO:
        perm = [(i, i + 1) for i in range(n - 1)]
        return jax.lax.ppermute(x, axis_name, perm)
    iota = jnp.arange(n, dtype=jnp.int32)
    own = (axis_idx == iota).astype(x.dtype).reshape((n,) + (1,) * x.ndim)
    gathered = jax.lax.psum(own * x[None], axis_name)     # (n, *x.shape)
    prev = (axis_idx - 1 == iota).astype(x.dtype).reshape((n,) + (1,) * x.ndim)
    return (prev * gathered).sum(axis=0)
