"""jax API compatibility shims.

The framework targets the modern ``jax.shard_map`` API (``check_vma``,
``axis_names``); older jax releases ship it as
``jax.experimental.shard_map.shard_map`` with ``check_rep`` and the
complementary ``auto`` set.  This adapter lets every call site use the new
signature unconditionally.
"""

from __future__ import annotations

from typing import FrozenSet, Optional

import jax


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None,
              axis_names: Optional[FrozenSet[str]] = None):
    """``jax.shard_map`` on new jax; experimental fallback on old jax."""
    if hasattr(jax, "shard_map"):
        kwargs = {}
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    kwargs = {}
    if check_vma is not None:        # omit to keep each version's default
        kwargs["check_rep"] = check_vma
    if axis_names is not None:
        # old API: `auto` is the complement — axes left in GSPMD auto mode
        kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def axis_size(axis_name: str):
    """``jax.lax.axis_size`` on new jax; psum-of-ones fallback on old jax
    (same value, resolved at trace time inside shard_map/pmap bodies)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
