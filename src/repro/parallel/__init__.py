"""Distribution: sharding rules, meshes, pipeline parallelism."""
from repro.parallel import sharding
