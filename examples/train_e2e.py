"""End-to-end training driver: reduced olmo-1b (~1.5M params scaled; the
same code path drives the full 1B+ configs on a real mesh) for a few
hundred steps with CDMT-dedup checkpointing — loss goes down, checkpoints
after the first move a fraction of the raw state bytes.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.checkpoint import CheckpointConfig
from repro.configs.base import get_config
from repro.core.registry import Registry
from repro.data import DataConfig
from repro.models.api import Model
from repro.optim import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.train_step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # ~100M-class architecture, reduced for CPU: same block structure
    cfg = get_config("olmo-1b", reduced=True).replace(
        n_layers=4, d_model=128, n_heads=8, n_kv_heads=8, d_ff=512)
    model = Model(cfg)
    print(f"model: {model.param_count():,} params (olmo family, reduced)")

    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, n_hosts=1, seed=0)
    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt=CheckpointConfig(lineage="train_e2e", n_groups=4,
                              every_steps=max(25, args.steps // 8)),
        train=TrainConfig(n_micro=2, adamw=AdamWConfig(lr=1e-3),
                          warmup_steps=20, total_steps=args.steps))
    tr = Trainer(model, data, tcfg, registry=Registry())

    def log(step, m):
        if step % 25 == 0 or step == args.steps - 1:
            print(f"  step {step:4d}  loss {m['loss']:.4f}  "
                  f"({m['step_s']*1e3:.0f} ms)")

    tr.run(on_step=log)

    first = sum(m["loss"] for m in tr.metrics_log[:10]) / 10
    last = sum(m["loss"] for m in tr.metrics_log[-10:]) / 10
    print(f"loss: first-10 avg {first:.3f} → last-10 avg {last:.3f}")
    assert last < first, "loss must decrease"

    print("\ncheckpoint wire accounting (CDMT dedup):")
    for info in tr.ckpt.history:
        print(f"  step {info.step:4d}: raw {info.raw_bytes/2**20:6.1f} MiB → "
              f"wire {info.total_wire_bytes/2**20:6.2f} MiB "
              f"({info.savings_vs_raw:.1%} saved)")


if __name__ == "__main__":
    main()
