"""Replication fleet demo: a primary registry, a standby following the
journal, a late-joining standby that bootstraps from a compacted snapshot
(never replaying trimmed history), an epoch roll that triggers automatic
wipe-and-resync, and a promotion after the primary is retired.

    PYTHONPATH=src python examples/replication_fleet.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import cdc
from repro.core.cdmt import CDMTParams
from repro.core.registry import PushRejected, Registry
from repro.delivery import (ImageClient, JournalFollower, LocalTransport,
                            RegistryServer, WireTransport)

CDC = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)
P = CDMTParams(window=4, rule_bits=2)


def blob(seed, n=60_000):
    return np.random.default_rng(seed).integers(
        0, 256, size=n, dtype=np.uint8).tobytes()


def main():
    # --- primary + first standby -------------------------------------------
    primary = Registry(cdmt_params=P)
    pub = ImageClient(LocalTransport(primary), cdc_params=CDC, cdmt_params=P)
    for i in range(3):
        pub.commit("app", f"v{i}", blob(i))
        pub.push("app", f"v{i}")

    server = RegistryServer(primary)
    s0 = Registry(cdmt_params=P)
    f0 = JournalFollower(s0, WireTransport(server), name="s0")
    applied = f0.catch_up()
    print(f"s0 joined early: replayed {applied} journal records, "
          f"tags={s0.tags('app')}")

    # the standby's acks trim the primary's log — bounded in-epoch memory
    log = primary.replication
    print(f"log after acks: head={log.head()} base={log.base} "
          f"({log.head() - log.base} records in memory)")
    assert log.base == log.head()

    # --- a late standby joins via snapshot bootstrap ------------------------
    # History below the base is gone; s1 adopts the compacted state instead.
    s1 = Registry(cdmt_params=P)
    f1 = JournalFollower(s1, WireTransport(server), name="s1")
    adopted = f1.catch_up()
    print(f"s1 joined late: snapshot bootstrap adopted {adopted} state "
          f"records (history was {log.head()}), tags={s1.tags('app')}")
    assert server.snapshot().snapshot_requests == 1

    # standbys are read-only until promoted
    s1pub = ImageClient(LocalTransport(s1), cdc_params=CDC, cdmt_params=P)
    s1pub.commit("app", "rogue", blob(99))
    try:
        s1pub.push("app", "rogue")
        raise AssertionError("read-only standby accepted a push")
    except PushRejected:
        print("s1 is read-only: push refused until promotion ✓")

    # --- epoch roll: automatic wipe-and-resync ------------------------------
    primary.sweep(retain_tags={"app": ["v2"]}, drop=True)
    f0.catch_up()
    snap = s0.metrics.snapshot()
    print(f"after GC sweep: s0 resynced to epoch {s0.replication.epoch}, "
          f"tags={s0.tags('app')} "
          f"(epoch_mismatch={snap.value('replication_epoch_mismatch_total', {}):.0f}, "
          f"bootstraps={snap.value('replication_bootstraps_total', {}):.0f})")
    assert s0.tags("app") == ["v2"]

    # --- primary retires, s0 takes the write role ---------------------------
    f0.promote()
    spub = ImageClient(LocalTransport(s0), cdc_params=CDC, cdmt_params=P)
    spub.commit("app", "v3", blob(3))
    spub.push("app", "v3")
    print(f"s0 promoted: accepted v3, tags={s0.tags('app')} ✓")


if __name__ == "__main__":
    main()
