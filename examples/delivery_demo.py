"""End-to-end tour of the delivery stack through the unified client API:
one ``ImageClient``, four transports — wire push, planned warm upgrade
through the concurrent frontend, the same upgrade over a real TCP socket
(bytes quoted to the byte, envelope included), and a peer-swarm rollout
with failover.

Run:  PYTHONPATH=src python examples/delivery_demo.py
"""

import numpy as np

from repro.core import cdc
from repro.core.registry import Registry
from repro.delivery import (ImageClient, RegistryServer,
                            SocketRegistryServer, SocketTransport, SwarmNode,
                            SwarmTracker, SwarmTransport, WireTransport)

CDC_PARAMS = cdc.CDCParams(mask_bits=11, min_size=256, max_size=16384)


def make_versions(n=6, size=400_000, seed=0):
    """A version chain: each release edits ~1% and inserts a few bytes
    (the insert is what shifts chunk boundaries)."""
    rng = np.random.default_rng(seed)
    data = bytearray(rng.integers(0, 256, size=size, dtype=np.uint8).tobytes())
    versions = [bytes(data)]
    for _ in range(n - 1):
        for _ in range(4):
            pos = int(rng.integers(0, len(data) - 200))
            data[pos:pos + 128] = rng.bytes(128)
        ins = int(rng.integers(0, len(data)))
        data[ins:ins] = rng.bytes(int(rng.integers(16, 512)))
        versions.append(bytes(data))
    return versions


def swarm_client(name, tracker, server, **kw):
    """An ImageClient whose transport fetches peers-first and serves back."""
    node = SwarmNode(name, cdc_params=CDC_PARAMS)
    transport = SwarmTransport(node, tracker, server, **kw)
    return ImageClient(transport, store=node.client.store,
                       indexes=node.client.indexes,
                       tag_trees=node.client.tag_trees,
                       cdc_params=CDC_PARAMS), node


def main():
    versions = make_versions()
    registry = Registry()
    server = RegistryServer(registry)
    tag = f"v{len(versions) - 1}"

    # -- publisher pushes every release over the wire ------------------------
    publisher = ImageClient(WireTransport(server), cdc_params=CDC_PARAMS)
    for i, v in enumerate(versions):
        publisher.commit("app", f"v{i}", v)
        st = publisher.push("app", f"v{i}")
        print(f"push v{i}: {st.chunks_moved}/{st.chunks_total} chunks, "
              f"{st.total_wire_bytes/1024:.1f} KiB on the wire "
              f"({st.savings_vs_raw:.0%} saved vs raw)")

    # -- a warm client plans, inspects, then executes its upgrade ------------
    node = ImageClient(WireTransport(server), cdc_params=CDC_PARAMS,
                       batch_chunks=32, pipeline_depth=4)
    node.pull("app", "v0")
    plan = node.plan_pull("app", tag)
    print(f"\nupgrade plan v0→{tag}: fetch {plan.chunks_to_fetch}/"
          f"{plan.chunks_total} chunks "
          f"(~{plan.expected_wire_bytes/1024:.1f} KiB, "
          f"{plan.comparisons} comparisons)")
    st = node.execute(plan)
    assert node.materialize("app", tag) == versions[-1]
    print(f"executed: {st.total_wire_bytes/1024:.1f} KiB moved vs "
          f"{st.raw_bytes/1024:.1f} KiB naive "
          f"({st.savings_vs_raw:.0%} saved, {st.rounds} pipelined rounds)")

    # -- the same upgrade over a real TCP socket -----------------------------
    with SocketRegistryServer(server) as sock_server:
        with SocketTransport(sock_server.address) as transport:
            remote = ImageClient(transport, cdc_params=CDC_PARAMS,
                                 batch_chunks=32, pipeline_depth=4)
            remote.pull("app", "v0")
            plan = remote.plan_pull("app", tag)
            st_s = remote.execute(plan)
            assert remote.materialize("app", tag) == versions[-1]
            # the plan quoted the socket bytes exactly, envelope included
            assert (st_s.index_bytes + st_s.recipe_bytes
                    + st_s.chunk_bytes) == plan.expected_wire_bytes
        ss = sock_server.snapshot()
        print(f"\nsocket upgrade v0→{tag}: quoted "
              f"{plan.expected_wire_bytes/1024:.1f} KiB, moved exactly that "
              f"over TCP ({ss.requests} requests on {ss.connections} "
              f"connection(s), {ss.egress_bytes/1024:.1f} KiB socket egress)")

    # -- swarm rollout: wave 1 drains the registry, wave 2 rides peers -------
    tracker = SwarmTracker()
    first, first_node = swarm_client("first", tracker, server)
    first.pull("app", tag)
    before = server.snapshot().egress_bytes
    late, _ = swarm_client("late", tracker, server)
    st2 = late.pull("app", tag)
    extra = server.snapshot().egress_bytes - before
    assert late.materialize("app", tag) == versions[-1]
    print(f"\nswarm follower: {st2.peer_offload_fraction:.0%} of chunk bytes "
          f"from peers; registry egress for it was only {extra/1024:.1f} KiB")

    # -- the provider dies mid-rollout: the next puller fails over -----------
    first_node.kill()
    unlucky, _ = swarm_client("unlucky", tracker, server)
    st3 = unlucky.pull("app", tag)
    assert unlucky.materialize("app", tag) == versions[-1]
    print(f"dead-peer failover: {st3.failovers} failed peer round(s) "
          f"absorbed, pull completed from "
          f"{', '.join(sorted(s for s, l in st3.sources.items() if l.chunks))}")

    s = server.snapshot()
    print(f"\nregistry frontend totals: {s.egress_bytes/1024:.1f} KiB out, "
          f"{s.ingress_bytes/1024:.1f} KiB in, cache hit rate "
          f"{server.cache_hit_rate():.0%}")


if __name__ == "__main__":
    main()
