"""End-to-end tour of the delivery stack: wire push, warm upgrade pull
through the concurrent frontend, and a peer-swarm rollout.

Run:  PYTHONPATH=src python examples/delivery_demo.py
"""

import numpy as np

from repro.core import cdc
from repro.core.registry import Registry
from repro.delivery import (DeltaSession, RegistryServer, SwarmNode,
                            SwarmTracker, swarm_pull)
from repro.core.pushpull import Client

CDC_PARAMS = cdc.CDCParams(mask_bits=11, min_size=256, max_size=16384)


def make_versions(n=6, size=400_000, seed=0):
    """A version chain: each release edits ~1% and inserts a few bytes
    (the insert is what shifts chunk boundaries)."""
    rng = np.random.default_rng(seed)
    data = bytearray(rng.integers(0, 256, size=size, dtype=np.uint8).tobytes())
    versions = [bytes(data)]
    for _ in range(n - 1):
        for _ in range(4):
            pos = int(rng.integers(0, len(data) - 200))
            data[pos:pos + 128] = rng.bytes(128)
        ins = int(rng.integers(0, len(data)))
        data[ins:ins] = rng.bytes(int(rng.integers(16, 512)))
        versions.append(bytes(data))
    return versions


def main():
    versions = make_versions()
    registry = Registry()
    server = RegistryServer(registry)

    # -- publisher pushes every release over the wire ------------------------
    publisher = Client(cdc_params=CDC_PARAMS)
    pub_sess = DeltaSession(publisher, server)
    for i, v in enumerate(versions):
        publisher.commit("app", f"v{i}", v)
        st = pub_sess.push("app", f"v{i}")
        print(f"push v{i}: {st.chunks_moved}/{st.chunks_total} chunks, "
              f"{st.total_wire_bytes/1024:.1f} KiB on the wire "
              f"({st.savings_vs_raw:.0%} saved vs raw)")

    # -- a warm client upgrades through the frontend -------------------------
    node = Client(cdc_params=CDC_PARAMS)
    sess = DeltaSession(node, server, batch_chunks=32, pipeline_depth=4)
    sess.pull("app", "v0")
    st = sess.pull("app", f"v{len(versions)-1}")
    assert node.materialize("app", f"v{len(versions)-1}") == versions[-1]
    print(f"\nwarm upgrade v0→v{len(versions)-1}: "
          f"{st.total_wire_bytes/1024:.1f} KiB moved vs "
          f"{st.raw_bytes/1024:.1f} KiB naive "
          f"({st.savings_vs_raw:.0%} saved, {st.rounds} pipelined rounds)")

    # -- swarm rollout: wave 1 drains the registry, wave 2 rides peers -------
    tracker = SwarmTracker()
    tag = f"v{len(versions)-1}"
    first = SwarmNode("first", cdc_params=CDC_PARAMS)
    swarm_pull(first, server, tracker, "app", tag)
    before = server.snapshot().egress_bytes
    late = SwarmNode("late", cdc_params=CDC_PARAMS)
    st2 = swarm_pull(late, server, tracker, "app", tag)
    extra = server.snapshot().egress_bytes - before
    assert late.client.materialize("app", tag) == versions[-1]
    print(f"\nswarm follower: {st2.peer_offload_fraction:.0%} of chunk bytes "
          f"from peers; registry egress for it was only {extra/1024:.1f} KiB")

    s = server.snapshot()
    print(f"\nregistry frontend totals: {s.egress_bytes/1024:.1f} KiB out, "
          f"{s.ingress_bytes/1024:.1f} KiB in, cache hit rate "
          f"{server.cache_hit_rate():.0%}")


if __name__ == "__main__":
    main()
