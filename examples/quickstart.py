"""Quickstart: the paper's pipeline end-to-end in ~60 lines.

Builds two versions of an artifact, CDC-chunks them, builds CDMT indexes,
pushes/pulls through a registry with the unified ``ImageClient`` API, and
prints the byte accounting that is the paper's point: only changed chunks
move.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import cdc, hashing
from repro.core.cdmt import CDMT, compare
from repro.core.registry import Registry
from repro.delivery import ImageClient, LocalTransport


def main():
    rng = np.random.default_rng(0)

    # --- two versions of a 2 MiB artifact: v2 inserts bytes mid-stream ----
    v1 = rng.bytes(2 * 2**20)
    v2 = v1[:2**20] + b"<-- a new dependency -->" + v1[2**20:]

    # --- 1. content-defined chunking --------------------------------------
    chunks1 = list(cdc.chunk_bytes(v1))
    chunks2 = list(cdc.chunk_bytes(v2))
    print(f"v1: {len(chunks1)} chunks, v2: {len(chunks2)} chunks "
          f"(avg {len(v1)//len(chunks1)} B)")

    # --- 2. CDMT indexes ----------------------------------------------------
    t1 = CDMT.build(hashing.fingerprint_many(chunks1))
    t2 = CDMT.build(hashing.fingerprint_many(chunks2))
    missing, comparisons = compare(t1, t2)
    print(f"CDMT: height {t2.height()}, {t2.n_nodes()} nodes, "
          f"index {t2.index_size_bytes()/1024:.1f} KiB")
    print(f"Alg.2: {len(missing)} changed chunks found in "
          f"{comparisons} comparisons (vs {len(chunks2)} flat lookups)")

    # --- 3. push/pull through a registry (unified client API) --------------
    registry = Registry()
    dev = ImageClient(LocalTransport(registry))
    dev.commit("app", "v1", v1)
    s1 = dev.push("app", "v1")
    dev.commit("app", "v2", v2)
    s2 = dev.push("app", "v2")
    print(f"push v1 (new image):   {s1.total_wire_bytes/2**20:.2f} MiB")
    print(f"push v2 (incremental): {s2.total_wire_bytes/2**20:.3f} MiB "
          f"({s2.savings_vs_raw:.1%} saved, {s2.chunks_moved} chunks moved)")

    prod = ImageClient(LocalTransport(registry))
    p1 = prod.pull("app", "v1")
    # a pull can be inspected before a chunk moves: plan, then execute
    plan = prod.plan_pull("app", "v2")
    print(f"plan v1→v2:            {plan.chunks_to_fetch}/{plan.chunks_total} "
          f"chunks to fetch, ~{plan.expected_wire_bytes/2**20:.3f} MiB "
          f"expected on the wire")
    p2 = prod.execute(plan)
    assert prod.materialize("app", "v2") == v2
    print(f"pull v1 (fresh host):  {p1.total_wire_bytes/2**20:.2f} MiB")
    print(f"pull v2 (upgrade):     {p2.total_wire_bytes/2**20:.3f} MiB "
          f"({p2.savings_vs_raw:.1%} saved)")
    print("reconstruction verified byte-for-byte ✓")


if __name__ == "__main__":
    main()
