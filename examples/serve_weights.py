"""Serving-weight distribution demo: a trained model version is published
to the registry; N serving hosts pull it (full cost once), then the model
is fine-tuned and republished — each host's upgrade pulls only the delta.
Finally the hosts serve batched requests.

    PYTHONPATH=src python examples/serve_weights.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.checkpoint import CheckpointConfig, DedupCheckpointManager
from repro.core.registry import Registry
from repro.models.api import build_model
from repro.serving import Request, ServeConfig, ServingEngine


def main():
    model = build_model("olmo-1b", reduced=True)
    params_v1 = model.init_params(jax.random.PRNGKey(0))

    registry = Registry()
    pub = DedupCheckpointManager(
        registry, CheckpointConfig(lineage="weights", n_groups=4))
    info1 = pub.save(params_v1, step=1)
    print(f"publish v1: {info1.raw_bytes/2**20:.1f} MiB raw → "
          f"{info1.total_wire_bytes/2**20:.2f} MiB wire")

    # --- serving fleet pulls v1 ---------------------------------------------
    hosts = []
    for h in range(3):
        mgr = DedupCheckpointManager(
            registry, CheckpointConfig(lineage="weights", n_groups=4))
        state, step, wire = mgr.restore(params_v1, step=1)
        print(f"host{h} pull v1: {sum(w.total_wire_bytes for w in wire)/2**20:.2f} MiB")
        hosts.append((mgr, state))

    # --- fine-tune: small update to a fraction of weights --------------------
    params_v2 = jax.tree.map(lambda p: p, params_v1)
    params_v2["lm_head"] = params_v1["lm_head"] + 1e-3
    info2 = pub.save(params_v2, step=2)
    print(f"publish v2 (fine-tune): wire {info2.total_wire_bytes/2**20:.2f} MiB "
          f"({info2.savings_vs_raw:.1%} saved)")

    # --- fleet upgrades: only the delta moves --------------------------------
    for h, (mgr, _) in enumerate(hosts):
        state, step, wire = mgr.restore(params_v2, step=2)
        moved = sum(w.chunk_bytes for w in wire)
        print(f"host{h} upgrade to v2: {moved/2**20:.3f} MiB of chunks moved")
        hosts[h] = (mgr, state)

    # --- serve ---------------------------------------------------------------
    params = jax.tree.map(lambda x: jax.numpy.asarray(x), hosts[0][1])
    engine = ServingEngine(model, params, ServeConfig(batch_size=4, max_len=192))
    rng = np.random.default_rng(0)
    reqs = [Request(id=i, prompt=rng.integers(0, model.cfg.vocab, 16,
                                              dtype=np.int32),
                    max_new_tokens=8) for i in range(8)]
    m = engine.serve(reqs)
    print(f"served {m['requests']} requests: {m['tokens_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
