"""Execute the fenced ``python`` blocks in README.md and docs/*.md.

The docs CI job runs this so documented examples cannot rot: every block
tagged ```` ```python ```` is executed against the real package.  Blocks
within one file share a namespace and run top to bottom — a markdown file
is a literate script, so later blocks may build on earlier ones.  Blocks in
other languages (``bash``, ``text``, untagged) are ignored.

Execution happens inside a temporary working directory, so examples may
create registries with ``directory=...`` relative paths freely.

Usage:  PYTHONPATH=$PWD/src python tools/check_docs.py [files...]
"""

from __future__ import annotations

import os
import pathlib
import sys
import tempfile
import traceback
from typing import Iterator, List, Tuple

REPO = pathlib.Path(__file__).resolve().parent.parent


def python_blocks(path: pathlib.Path) -> Iterator[Tuple[int, str]]:
    """Yield ``(first_line_number, source)`` for each ```python fence."""
    lines = path.read_text(encoding="utf-8").splitlines()
    block: List[str] = []
    start = 0
    in_python = False
    for i, line in enumerate(lines, 1):
        stripped = line.strip()
        if not in_python and stripped == "```python":
            in_python = True
            start = i + 1
            block = []
        elif in_python and stripped == "```":
            in_python = False
            yield start, "\n".join(block)
        elif in_python:
            block.append(line)
    if in_python:
        raise SystemExit(f"{path}: unterminated ```python fence at "
                         f"line {start - 1}")


def run_file(path: pathlib.Path) -> int:
    """Run every python block of one file in a shared namespace; returns
    the number of blocks executed."""
    namespace = {"__name__": "__docs__", "__file__": str(path)}
    count = 0
    for lineno, source in python_blocks(path):
        code = compile(source, f"{path}:{lineno}", "exec")
        try:
            exec(code, namespace)
        except Exception:
            traceback.print_exc()
            raise SystemExit(
                f"\nFAILED: {path} block at line {lineno} — the documented "
                f"example no longer executes; fix the doc or the code")
        count += 1
    return count


def main(argv: List[str]) -> None:
    if argv:
        files = [pathlib.Path(a).resolve() for a in argv]
    else:
        files = [REPO / "README.md"]
        files += sorted((REPO / "docs").glob("*.md"))
    total = 0
    original_cwd = os.getcwd()
    for path in files:
        with tempfile.TemporaryDirectory() as scratch:
            os.chdir(scratch)
            try:
                n = run_file(path)
            finally:
                os.chdir(original_cwd)
        print(f"{path.relative_to(REPO)}: {n} block(s) OK")
        total += n
    print(f"docs OK: {total} python block(s) executed")


if __name__ == "__main__":
    main(sys.argv[1:])
