"""End-to-end observability smoke — the CI gate for the metrics pipeline.

One real socket rollout with metrics + tracing enabled, then every
observability surface is exercised and checked:

  1. a live ``Op.METRICS`` scrape off the running ``SocketRegistryServer``;
  2. the scraped snapshot must carry the expected series (request-latency
     histograms per op, cache hits/misses, socket envelope accounting) and
     agree with the in-process snapshot;
  3. its Prometheus exposition must round-trip through the parser;
  4. a second scrape, after more traffic, must be monotonically ≥ the
     first on every counter (``check_monotonic``);
  5. client-side metric byte totals must equal the pull's
     ``TransferReport`` byte for byte;
  6. the tracer must have recorded one span tree per pull, printable by
     ``tools/trace_dump.py``.

Exits non-zero with a message on the first violated check.

Usage:  PYTHONPATH=$PWD/src python tools/obs_smoke.py
"""

from __future__ import annotations

import sys

from repro.core import cdc
from repro.core.cdmt import CDMTParams
from repro.core.registry import Registry
from repro.delivery import (ImageClient, LocalTransport, RegistryServer,
                            SocketRegistryServer, SocketTransport)
from repro.obs import (Tracer, check_monotonic, parse_prometheus_text,
                       to_prometheus_text)

CDC_PARAMS = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)
CDMT_PARAMS = CDMTParams(window=4, rule_bits=2)


def fail(msg: str) -> None:
    print(f"FAIL: {msg}")
    sys.exit(1)


def check(cond: bool, msg: str) -> None:
    if not cond:
        fail(msg)
    print(f"ok: {msg}")


def main() -> int:
    reg = Registry(cdmt_params=CDMT_PARAMS)
    pub = ImageClient(LocalTransport(reg), cdc_params=CDC_PARAMS,
                      cdmt_params=CDMT_PARAMS)
    blob = bytes(range(256)) * 3000
    pub.commit("app", "v1", blob)
    pub.push("app", "v1")
    pub.commit("app", "v2", blob + b"delta" * 800)
    pub.push("app", "v2")

    srv = RegistryServer(reg)
    tracer = Tracer(enabled=True)
    with SocketRegistryServer(srv) as sock_srv, \
            SocketTransport(sock_srv.address) as transport:
        cl = ImageClient(transport, cdc_params=CDC_PARAMS,
                         cdmt_params=CDMT_PARAMS, tracer=tracer)
        rep1 = cl.pull("app", "v1")

        # -- first scrape: schema + agreement with the in-process snapshot
        scraped = transport.scrape_metrics()
        local = srv.metrics.snapshot()
        for name in ("registry_requests_total", "registry_request_seconds",
                     "registry_egress_bytes_total", "cache_hits_total",
                     "cache_misses_total", "socket_requests_total",
                     "socket_egress_bytes_total"):
            check(scraped.family(name) is not None,
                  f"scrape carries {name}")
        for op in ("index", "recipe", "want"):
            got = scraped.histogram("registry_request_seconds", {"op": op})
            want = got is not None and got.count >= 1
            check(want, f"request-latency histogram has {op} samples")
        check(scraped.value("cache_misses_total", {})
              == local.value("cache_misses_total", {}),
              "scraped cache counters equal in-process snapshot")

        # -- exposition round-trips
        text = to_prometheus_text(scraped)
        parsed = parse_prometheus_text(text)
        check(len(parsed) > 50, f"prometheus exposition parses "
                                f"({len(parsed)} samples)")

        # -- more traffic, second scrape: counters are monotonic
        rep2 = cl.pull("app", "v2")
        scraped2 = transport.scrape_metrics()
        violations = check_monotonic(scraped, scraped2)
        check(violations == [],
              f"counters monotonic across scrapes {violations or ''}")

        # -- client metric bytes equal the reports, to the byte
        snap = cl.metrics.snapshot()
        total = snap.value("client_wire_bytes_total",
                           {"transport": "socket"})
        check(total == rep1.total_wire_bytes + rep2.total_wire_bytes,
              "client byte counters equal TransferReport totals")

    # -- tracing captured both pulls; the dump tool renders them
    roots = tracer.take()
    check(len(roots) == 2, f"one span tree per pull ({len(roots)})")
    check(roots[0].name == "pull" and roots[0].children,
          "span tree rooted at 'pull' with children")
    import json

    from trace_dump import dump  # sibling script; sys.path[0] is tools/
    n = dump(json.dumps([sp.to_dict() for sp in roots]))
    check(n == 2, "trace_dump renders the recorded trees")
    print("obs smoke: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
