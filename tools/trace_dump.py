"""Pretty-print recorded pull-trace span trees.

Input is the JSON shape ``Span.to_dict`` produces — either a single span
object or a list of them — read from a file or stdin.  Output is one
indented tree per root span with durations, self-time, and attributes::

    pull  5.7ms  (self 0.1ms)  lineage=app tag=v2
      plan_pull  1.2ms  chunks_missing=141 ...
      execute  4.5ms  (self 0.5ms)  transport=socket ...
        fetch_batch  1.6ms  batch=0 chunks=64
        ...

``--demo`` runs a small in-process traced pull and dumps it — a smoke test
for the tracing pipeline that needs no prior capture.

Usage:  PYTHONPATH=$PWD/src python tools/trace_dump.py trace.json
        ... | PYTHONPATH=$PWD/src python tools/trace_dump.py -
        PYTHONPATH=$PWD/src python tools/trace_dump.py --demo
"""

from __future__ import annotations

import json
import sys
from typing import List

from repro.obs import Span


def _fmt_ms(seconds: float) -> str:
    return f"{seconds * 1e3:.2f}ms"


def _fmt_attrs(attrs: dict) -> str:
    return " ".join(f"{k}={v}" for k, v in sorted(attrs.items()))


def render(span: Span, indent: int = 0, out=sys.stdout) -> None:
    self_time = span.duration - sum(c.duration for c in span.children)
    parts = ["  " * indent + span.name, _fmt_ms(span.duration)]
    if span.children:
        parts.append(f"(self {_fmt_ms(max(0.0, self_time))})")
    if span.attrs:
        parts.append(_fmt_attrs(span.attrs))
    print("  ".join(parts), file=out)
    for child in span.children:
        render(child, indent + 1, out)


def load_spans(text: str) -> List[Span]:
    obj = json.loads(text)
    if isinstance(obj, dict):
        obj = [obj]
    if not isinstance(obj, list):
        raise ValueError("expected a span object or a list of them")
    return [Span.from_dict(entry) for entry in obj]


def dump(text: str, out=sys.stdout) -> int:
    spans = load_spans(text)
    for i, span in enumerate(spans):
        if i:
            print(file=out)
        render(span, out=out)
    return len(spans)


def _demo() -> str:
    """A real traced socket pull, serialized — what a capture looks like."""
    from repro.core import cdc
    from repro.core.cdmt import CDMTParams
    from repro.core.registry import Registry
    from repro.delivery import (ImageClient, LocalTransport, RegistryServer,
                                SocketRegistryServer, SocketTransport)
    from repro.obs import Tracer

    params = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)
    tree_params = CDMTParams(window=4, rule_bits=2)
    reg = Registry(cdmt_params=tree_params)
    pub = ImageClient(LocalTransport(reg), cdc_params=params,
                      cdmt_params=tree_params)
    blob = bytes(range(256)) * 2000
    pub.commit("demo", "v1", blob)
    pub.push("demo", "v1")
    pub.commit("demo", "v2", blob + b"tail" * 600)
    pub.push("demo", "v2")

    tracer = Tracer(enabled=True)
    with SocketRegistryServer(RegistryServer(reg)) as sock_srv, \
            SocketTransport(sock_srv.address) as transport:
        cl = ImageClient(transport, cdc_params=params,
                         cdmt_params=tree_params, tracer=tracer)
        cl.pull("demo", "v1")
        cl.pull("demo", "v2")
    return json.dumps([sp.to_dict() for sp in tracer.take()])


def main(argv: List[str]) -> int:
    if "--demo" in argv:
        dump(_demo())
        return 0
    if not argv or argv[0] == "-":
        text = sys.stdin.read()
    else:
        with open(argv[0]) as f:
            text = f.read()
    if not dump(text):
        print("no spans in input", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
