#!/usr/bin/env python
"""Repo-specific static analysis gate — six analyzers over the delivery
stack: guarded-by lint, lock-order analyzer, wire-spec drift checker,
layer-import analyzer, error-taxonomy (err-contract) analyzer, and the
crash-ordering (durability) lint.

Usage:
    PYTHONPATH=src python tools/analyze.py                # report findings
    PYTHONPATH=src python tools/analyze.py --strict       # + doc-sync check
    PYTHONPATH=src python tools/analyze.py --write-docs   # regen generated
                                                          #   doc sections
    PYTHONPATH=src python tools/analyze.py --self-test    # prove the gate
                                                          #   bites
    PYTHONPATH=src python tools/analyze.py --format github  # CI annotations
    PYTHONPATH=src python tools/analyze.py --format json    # machine output

Exit status: 0 when clean, 1 when any analyzer reports a finding (or the
self-test fails to catch the seeded broken fixtures).  The default text
format prints ``path:line: [analyzer] message`` so terminals link straight
to the site; ``--format github`` emits ``::error`` workflow annotations;
``--format json`` prints one JSON object with findings and per-analyzer
stats.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.analysis import (durability, errcontract, guarded,  # noqa: E402
                            layers, lockorder, wiredrift)
from repro.analysis.report import Finding  # noqa: E402

WIRE_DOC = "docs/WIRE_PROTOCOL.md"
CONCURRENCY_DOC = "docs/CONCURRENCY.md"
ARCH_DOC = "docs/ARCHITECTURE.md"


def scan_paths() -> list:
    paths = []
    for pattern in ("src/repro/core/*.py", "src/repro/delivery/*.py",
                    "src/repro/obs/*.py"):
        paths.extend(glob.glob(pattern))
    return sorted(paths)


# ---------------------------------------------------- generated doc sections

def _markers(section: str):
    return (f"<!-- BEGIN GENERATED: {section} "
            f"(tools/analyze.py --write-docs) -->",
            f"<!-- END GENERATED: {section} -->")


def _sections(lo, ly) -> list:
    """(analyzer, doc, section name, generated body) for every generated
    doc section the gate owns."""
    return [
        ("lock-order", CONCURRENCY_DOC, "lock-hierarchy",
         lockorder.hierarchy_markdown(lo)),
        ("layers", ARCH_DOC, "layer-map", layers.layers_markdown(ly)),
    ]


def _render(section: str, body: str) -> str:
    begin, end = _markers(section)
    return begin + "\n\n" + body + "\n" + end


def check_doc_sync(lo, ly) -> list:
    """Every generated doc section must match what its analyzer derives
    from the code right now."""
    findings = []
    for analyzer, doc, section, body in _sections(lo, ly):
        if not os.path.exists(doc):
            findings.append(Finding(
                analyzer, doc, 1,
                "missing — run tools/analyze.py --write-docs"))
            continue
        with open(doc, "r", encoding="utf-8") as f:
            text = f.read()
        mb, me = _markers(section)
        begin, end = text.find(mb), text.find(me)
        if begin < 0 or end < 0:
            findings.append(Finding(
                analyzer, doc, 1,
                f"generated {section} markers missing — run "
                f"tools/analyze.py --write-docs"))
            continue
        current = text[begin:end + len(me)]
        if current.strip() != _render(section, body).strip():
            line = text[:begin].count("\n") + 1
            findings.append(Finding(
                analyzer, doc, line,
                f"generated {section} section is stale — run "
                f"tools/analyze.py --write-docs and commit"))
    return findings


def write_docs(lo, ly) -> None:
    for analyzer, doc, section, body in _sections(lo, ly):
        with open(doc, "r", encoding="utf-8") as f:
            text = f.read()
        mb, me = _markers(section)
        begin, end = text.find(mb), text.find(me)
        if begin < 0 or end < 0:
            raise SystemExit(f"{doc}: {section} generated-section markers "
                             f"not found")
        new = text[:begin] + _render(section, body) + text[end + len(me):]
        with open(doc, "w", encoding="utf-8") as f:
            f.write(new)
        print(f"{doc}: {section} section regenerated")


# ---------------------------------------------------------------- analyzers

def run_analyzers(strict: bool):
    paths = scan_paths()
    g_findings, g_stats = guarded.check_files(paths)
    lo = lockorder.analyze_files(paths)
    w_findings, w_stats = wiredrift.check_all(WIRE_DOC)
    ly = layers.analyze_paths(paths)
    e_findings, e_stats = errcontract.analyze_files(paths)
    d_findings, d_stats = durability.check_files(paths)
    findings = (list(g_findings) + list(lo.findings) + list(w_findings)
                + list(ly.findings) + list(e_findings) + list(d_findings))
    if strict:
        findings.extend(check_doc_sync(lo, ly))
    stats = {"guarded_by": g_stats, "lock_order": lo.stats,
             "wire_drift": w_stats, "layers": ly.stats,
             "err_contract": e_stats, "durability": d_stats}
    return findings, stats, lo, ly


# ---------------------------------------------------------------- self-test

def self_test() -> int:
    """The gate must bite: every seeded broken fixture must be caught."""
    failures = []
    caught = []

    fixture = "tests/fixtures/analysis_broken.py"
    g_findings = guarded.check_file(fixture)
    if not any("outside" in f.message for f in g_findings):
        failures.append(f"guarded-by lint missed the unguarded field in "
                        f"{fixture}")
    lo = lockorder.analyze_files([fixture], check_ranks=False)
    if not any("cycle" in f.message for f in lo.findings):
        failures.append(f"lock-order analyzer missed the inversion cycle "
                        f"in {fixture}")
    caught += list(g_findings) + list(lo.findings)

    doc = "tests/fixtures/wire_spec_broken.md"
    w_findings, _ = wiredrift.check_doc(doc)
    messages = "\n".join(f.message for f in w_findings)
    if "METRICS" not in messages:
        failures.append(f"wire-drift checker missed the undocumented "
                        f"METRICS frame in {doc}")
    if "no matching enum member" not in messages:
        failures.append(f"wire-drift checker missed the phantom frame row "
                        f"in {doc}")
    if "but the enum member is" not in messages:
        failures.append(f"wire-drift checker missed the misnamed op row "
                        f"in {doc}")
    caught += list(w_findings)

    fixture = "tests/fixtures/layers_broken.py"
    assignments = layers._load_doc_assignments(ARCH_DOC)
    assignments["layers_broken"] = 2
    exceptions = dict(layers.LAYER_EXCEPTIONS)
    exceptions[("layers_broken", "wire")] = "seeded self-test allowlisting"
    ly = layers.analyze_paths([fixture], assignments=assignments,
                              exceptions=exceptions)
    if not any("upward import" in f.message for f in ly.findings):
        failures.append(f"layer analyzer missed the module-level upward "
                        f"import in {fixture}")
    if not any("module level" in f.message for f in ly.findings):
        failures.append(f"layer analyzer missed the eager allowlisted "
                        f"edge in {fixture}")
    caught += list(ly.findings)

    fixture = "tests/fixtures/errcontract_broken.py"
    e_findings, _ = errcontract.analyze_files([fixture])
    messages = "\n".join(f.message for f in e_findings)
    if "raise of banned type KeyError" not in messages:
        failures.append(f"err-contract analyzer missed the bare KeyError "
                        f"raise in {fixture}")
    if "api-boundary method 'BrokenStore.fetch' can leak KeyError" \
            not in messages:
        failures.append(f"err-contract analyzer missed the KeyError leak "
                        f"through BrokenStore.fetch in {fixture}")
    if "safe_fetch" in messages:
        failures.append(f"err-contract analyzer flagged the taxonomy-"
                        f"wrapped safe_fetch in {fixture}")
    caught += list(e_findings)

    fixture = "tests/fixtures/durability_broken.py"
    broken_paths = {("BrokenRegistry", "receive_push")}
    d_findings = durability.check_file(fixture, commit_paths=broken_paths,
                                       journaled_paths=broken_paths)
    messages = "\n".join(f.message for f in d_findings)
    if "without a preceding os.fsync" not in messages:
        failures.append(f"durability lint missed the rename-without-fsync "
                        f"in {fixture}")
    if "never fsynced afterwards" not in messages:
        failures.append(f"durability lint missed the missing directory "
                        f"fsync in {fixture}")
    if "before chunks.sync()" not in messages:
        failures.append(f"durability lint missed the record-before-chunks "
                        f"commit in {fixture}")
    if "mutates in-memory state" not in messages:
        failures.append(f"durability lint missed the mutate-before-append "
                        f"in {fixture}")
    caught += list(d_findings)

    for f in caught:
        print(f"  caught: {f}")
    if failures:
        for msg in failures:
            print(f"SELF-TEST FAIL: {msg}", file=sys.stderr)
        return 1
    print("self-test OK: all seeded defects caught")
    return 0


# --------------------------------------------------------------------- main

def print_stats(stats) -> None:
    g, lo = stats["guarded_by"], stats["lock_order"]
    w, ly = stats["wire_drift"], stats["layers"]
    e, d = stats["err_contract"], stats["durability"]
    print(f"guarded-by: {g['files']} files, {g['classes']} classes, "
          f"{g['guarded_fields']} guarded + "
          f"{g['external_fields']} external fields, "
          f"{g['accesses_checked']} accesses checked")
    print(f"lock-order: {lo['locks']} locks, "
          f"{lo['edges']} acquisition edges")
    print(f"wire-drift: {w['enum_members']} enum members vs "
          f"{w['doc_rows']} doc rows, {w['round_trips']} frame "
          f"round-trips, {w['sizing_checks']} sizing identities")
    print(f"layers: {ly['modules']} modules, {ly['edges']} import edges "
          f"({ly['lazy_edges']} lazy, {ly['upward_edges']} upward, "
          f"{ly['exceptions']} allowlisted)")
    print(f"err-contract: {e['boundaries']} api boundaries, "
          f"{e['raise_sites']} raise sites, "
          f"{e['calls_resolved']} calls resolved, "
          f"{e['pragmas']} pragmas")
    print(f"durability: {d['replace_sites']} rename sites, "
          f"{d['commit_paths']} commit paths, "
          f"{d['journaled_paths']} journaled paths, "
          f"{d['pragmas']} pragmas")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--strict", action="store_true",
                        help="also fail when a generated doc section "
                             "(CONCURRENCY.md lock hierarchy, "
                             "ARCHITECTURE.md layer map) is stale")
    parser.add_argument("--write-docs", action="store_true",
                        help="regenerate the generated sections of "
                             "docs/CONCURRENCY.md and docs/ARCHITECTURE.md")
    parser.add_argument("--self-test", action="store_true",
                        help="verify the analyzers catch the seeded "
                             "broken fixtures")
    parser.add_argument("--format", choices=("text", "github", "json"),
                        default="text",
                        help="finding output format: terminal text, "
                             "GitHub workflow annotations, or one JSON "
                             "object")
    args = parser.parse_args(argv)
    os.chdir(ROOT)

    if args.self_test:
        return self_test()

    findings, stats, lo, ly = run_analyzers(args.strict)
    if args.write_docs:
        write_docs(lo, ly)
        regenerated = {CONCURRENCY_DOC, ARCH_DOC}
        findings = [f for f in findings if f.path not in regenerated]

    if args.format == "json":
        print(json.dumps({
            "findings": [{"analyzer": f.analyzer, "path": f.path,
                          "line": f.line, "message": f.message}
                         for f in findings],
            "stats": stats,
            "clean": not findings,
        }, indent=2, sort_keys=True))
        return 1 if findings else 0

    for f in findings:
        if args.format == "github":
            print(f"::error file={f.path},line={f.line},"
                  f"title={f.analyzer}::{f.message}")
        else:
            print(f)
    print_stats(stats)
    if findings:
        print(f"{len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("analysis clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
