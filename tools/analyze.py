#!/usr/bin/env python
"""Repo-specific static analysis gate: guarded-by lint, lock-order
analyzer, wire-spec drift checker.

Usage:
    PYTHONPATH=src python tools/analyze.py              # report findings
    PYTHONPATH=src python tools/analyze.py --strict     # + doc-sync check
    PYTHONPATH=src python tools/analyze.py --write-docs # regen CONCURRENCY.md
    PYTHONPATH=src python tools/analyze.py --self-test  # prove the gate bites

Exit status: 0 when clean, 1 when any analyzer reports a finding (or the
self-test fails to catch the seeded broken fixtures).  Findings print as
``path:line: [analyzer] message`` so terminals and CI annotations link
straight to the site.
"""

from __future__ import annotations

import argparse
import glob
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.analysis import guarded, lockorder, wiredrift  # noqa: E402
from repro.analysis.report import Finding  # noqa: E402

WIRE_DOC = "docs/WIRE_PROTOCOL.md"
CONCURRENCY_DOC = "docs/CONCURRENCY.md"
GEN_BEGIN = ("<!-- BEGIN GENERATED: lock-hierarchy "
             "(tools/analyze.py --write-docs) -->")
GEN_END = "<!-- END GENERATED: lock-hierarchy -->"


def scan_paths() -> list:
    paths = []
    for pattern in ("src/repro/core/*.py", "src/repro/delivery/*.py",
                    "src/repro/obs/*.py"):
        paths.extend(glob.glob(pattern))
    return sorted(paths)


def generated_section(result) -> str:
    return (GEN_BEGIN + "\n\n" + lockorder.hierarchy_markdown(result)
            + "\n" + GEN_END)


def check_doc_sync(result) -> list:
    """The generated lock-hierarchy section of CONCURRENCY.md must match
    what the analyzer derives from the code right now."""
    if not os.path.exists(CONCURRENCY_DOC):
        return [Finding("lock-order", CONCURRENCY_DOC, 1,
                        "missing — run tools/analyze.py --write-docs")]
    with open(CONCURRENCY_DOC, "r", encoding="utf-8") as f:
        text = f.read()
    begin, end = text.find(GEN_BEGIN), text.find(GEN_END)
    if begin < 0 or end < 0:
        return [Finding("lock-order", CONCURRENCY_DOC, 1,
                        "generated lock-hierarchy markers missing — run "
                        "tools/analyze.py --write-docs")]
    current = text[begin:end + len(GEN_END)]
    if current.strip() != generated_section(result).strip():
        line = text[:begin].count("\n") + 1
        return [Finding("lock-order", CONCURRENCY_DOC, line,
                        "generated lock-hierarchy section is stale — run "
                        "tools/analyze.py --write-docs and commit")]
    return []


def write_docs(result) -> None:
    with open(CONCURRENCY_DOC, "r", encoding="utf-8") as f:
        text = f.read()
    begin, end = text.find(GEN_BEGIN), text.find(GEN_END)
    if begin < 0 or end < 0:
        raise SystemExit(f"{CONCURRENCY_DOC}: generated-section markers "
                         f"not found")
    new = text[:begin] + generated_section(result) + text[end + len(GEN_END):]
    with open(CONCURRENCY_DOC, "w", encoding="utf-8") as f:
        f.write(new)
    print(f"{CONCURRENCY_DOC}: lock-hierarchy section regenerated")


def run_analyzers(strict: bool):
    paths = scan_paths()
    g_findings, g_stats = guarded.check_files(paths)
    lo = lockorder.analyze_files(paths)
    w_findings, w_stats = wiredrift.check_all(WIRE_DOC)
    findings = list(g_findings) + list(lo.findings) + list(w_findings)
    if strict:
        findings.extend(check_doc_sync(lo))
    return findings, lo, g_stats, lo.stats, w_stats


def self_test() -> int:
    """The gate must bite: the seeded broken fixtures must be caught."""
    failures = []

    fixture = "tests/fixtures/analysis_broken.py"
    g_findings = guarded.check_file(fixture)
    if not any("outside" in f.message for f in g_findings):
        failures.append(f"guarded-by lint missed the unguarded field in "
                        f"{fixture}")
    lo = lockorder.analyze_files([fixture], check_ranks=False)
    if not any("cycle" in f.message for f in lo.findings):
        failures.append(f"lock-order analyzer missed the inversion cycle "
                        f"in {fixture}")

    doc = "tests/fixtures/wire_spec_broken.md"
    w_findings, _ = wiredrift.check_doc(doc)
    messages = "\n".join(f.message for f in w_findings)
    if "METRICS" not in messages:
        failures.append(f"wire-drift checker missed the undocumented "
                        f"METRICS frame in {doc}")
    if "no matching enum member" not in messages:
        failures.append(f"wire-drift checker missed the phantom frame row "
                        f"in {doc}")
    if "but the enum member is" not in messages:
        failures.append(f"wire-drift checker missed the misnamed op row "
                        f"in {doc}")

    for f in g_findings + lo.findings + w_findings:
        print(f"  caught: {f}")
    if failures:
        for msg in failures:
            print(f"SELF-TEST FAIL: {msg}", file=sys.stderr)
        return 1
    print("self-test OK: all seeded defects caught")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--strict", action="store_true",
                        help="also fail when docs/CONCURRENCY.md's "
                             "generated section is stale")
    parser.add_argument("--write-docs", action="store_true",
                        help="regenerate the lock-hierarchy section of "
                             "docs/CONCURRENCY.md")
    parser.add_argument("--self-test", action="store_true",
                        help="verify the analyzers catch the seeded "
                             "broken fixtures")
    args = parser.parse_args(argv)
    os.chdir(ROOT)

    if args.self_test:
        return self_test()

    findings, lo, g_stats, lo_stats, w_stats = run_analyzers(args.strict)
    if args.write_docs:
        write_docs(lo)
        findings = [f for f in findings if f.path != CONCURRENCY_DOC]
    for f in findings:
        print(f)
    print(f"guarded-by: {g_stats['files']} files, "
          f"{g_stats['classes']} classes, "
          f"{g_stats['guarded_fields']} guarded + "
          f"{g_stats['external_fields']} external fields, "
          f"{g_stats['accesses_checked']} accesses checked")
    print(f"lock-order: {lo_stats['locks']} locks, "
          f"{lo_stats['edges']} acquisition edges")
    print(f"wire-drift: {w_stats['enum_members']} enum members vs "
          f"{w_stats['doc_rows']} doc rows, "
          f"{w_stats['round_trips']} frame round-trips, "
          f"{w_stats['sizing_checks']} sizing identities")
    if findings:
        print(f"{len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("analysis clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
